//! Iteration-level continuous-batching scheduler.
//!
//! Replaces the old batch-at-a-time `Batcher` (which padded partial
//! batches by duplicating a real lane and decoded every lane to the
//! batch max). The scheduler owns the admission queue, the page
//! allocator ([`KvPool`]) and the logical lane table; each
//! [`Engine::step`](super::Engine::step) runs ONE scheduler tick. Lanes
//! finish independently — per-request `max_new_tokens` and stop tokens —
//! and a freed lane is backfilled from the queue on the very next
//! iteration, so no decode slot is ever spent on a finished or
//! duplicated request.
//!
//! **Occupancy is single-authority** (PR 3): the in-flight entry owns
//! BOTH the request state and its [`LaneKv`] cache map (position + page
//! table). The earlier split — a scheduler lane table next to a
//! `KvPool` slot table, updated in lockstep — is gone; the pool is now
//! only the free-list allocator.
//!
//! **Admission is by free pages.** A request reserves
//! `ceil((prompt + budget) / page_len)` pages when it binds and releases
//! them the moment it retires. In the dense configuration (`page_len ==
//! max_seq`, one page per lane) that degenerates to exactly the PR 2
//! free-lane rule, bit-for-bit; in a paged configuration short requests
//! reserve less, so MORE logical lanes fit the same memory
//! (`tests/kv_paging.rs` gates the ≥1.5× concurrency win). Admission is
//! FIFO with head-of-line blocking: if the head request's pages don't
//! fit, nothing behind it jumps the queue (no starvation).
//!
//! Admission prefill is governed by a [`PrefillPolicy`]:
//!
//! * [`PrefillPolicy::Blocking`] — the PR 1 behavior: one whole-pool
//!   prefill invocation warms every admitted lane before the tick's
//!   decode iteration.
//! * [`PrefillPolicy::Chunked`] — prompts stream into their lanes in
//!   `chunk_len`-token slices interleaved with decode iterations; a
//!   request occupying a lane mid-prompt is in
//!   [`RequestPhase::Prefilling`] and joins decode once its prompt is
//!   cache-resident.
//!
//! Admission policy is capability-driven: with a per-lane-position
//! backend (`BackendSpec::per_lane_pos`) any free lane is backfilled
//! immediately; with an aligned-only backend the scheduler gang-admits
//! into an all-free pool (still padding-free, still stop-token aware).

use std::collections::VecDeque;
use std::time::Instant;

use crate::anyhow::{anyhow, Result};

use super::backend::{LaneStep, PagedStep};
use super::kv::{sim_rows_amax_k, sim_rows_amax_v, KvPool, LaneKv, PageCodec,
                PageHeader, PrefixIndex, ReservationPolicy};
use super::request::{FinishReason, GenRequest, GenResult};

/// How admission prefill shares the engine with decode iterations.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum PrefillPolicy {
    /// Whole-prompt, whole-pool admission prefill (PR 1 behavior): the
    /// tick's decode iteration waits for the full prefill invocation.
    #[default]
    Blocking,
    /// Stream prompts in `chunk_len`-token slices interleaved with
    /// decode iterations.
    Chunked {
        /// Prompt tokens per prefill chunk (≥ 1; the final chunk of a
        /// prompt may be shorter).
        chunk_len: usize,
        /// When true (the default posture), at most ONE chunk is issued
        /// per tick so resident lanes keep their decode cadence; when
        /// false every prefilling lane gets a chunk each tick (drains
        /// admissions faster at the decode lanes' expense).
        decode_priority: bool,
    },
    /// Chunked prefill whose chunk width floats between `min_chunk` and
    /// `max_chunk`, driven per tick by the admission-queue depth (the
    /// front door's [`super::frontdoor::AdaptiveChunk`] controller): a
    /// backlog grows the chunk to drain prompts faster, an empty queue
    /// shrinks it to protect decode cadence. Admission/phase machinery
    /// is identical to [`PrefillPolicy::Chunked`]; only the per-tick
    /// width moves, and width only changes modeled TIMING — mock and
    /// modeled token streams are position-deterministic, so bytes never
    /// depend on it.
    Adaptive {
        /// Smallest chunk the controller issues (≥ 1).
        min_chunk: usize,
        /// Largest chunk the controller grows to (≥ `min_chunk`).
        max_chunk: usize,
        /// Same decode-cadence knob as [`PrefillPolicy::Chunked`].
        decode_priority: bool,
    },
}

impl PrefillPolicy {
    /// Chunked with the decode-protecting default.
    pub fn chunked(chunk_len: usize) -> Self {
        PrefillPolicy::Chunked { chunk_len, decode_priority: true }
    }

    /// Adaptive chunking with the decode-protecting default.
    pub fn adaptive(min_chunk: usize, max_chunk: usize) -> Self {
        PrefillPolicy::Adaptive { min_chunk, max_chunk, decode_priority: true }
    }

    /// Whether this policy streams prompts in chunks (either fixed or
    /// adaptive width) rather than blocking whole-pool prefill.
    pub fn is_chunked(&self) -> bool {
        !matches!(self, PrefillPolicy::Blocking)
    }
}

/// Where a lane-resident request is in its lifecycle.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RequestPhase {
    /// The prompt is streaming into the lane's cache; `next_chunk` is
    /// the index of the next chunk to issue (chunk 0 starts at cache
    /// position 0).
    Prefilling { next_chunk: usize },
    /// The prompt is resident; the lane joins decode iterations.
    Decoding,
}

/// A retired request paired with its admission sequence number, so
/// drain-style callers can restore submission order across iterations.
pub type Completion = (u64, GenResult);

/// One planned prefill chunk: feed `tokens` into `lane` starting at
/// cache position `start_pos`. `last` marks the chunk that completes
/// the prompt (its logits yield the request's first generated token).
#[derive(Debug, Clone, Copy)]
pub struct ChunkPlan<'a> {
    pub lane: usize,
    pub start_pos: usize,
    pub tokens: &'a [i32],
    pub last: bool,
}

/// Point-in-time page accounting for the metrics surface.
#[derive(Debug, Clone, Copy, Default)]
pub struct PageStats {
    pub total_pages: usize,
    pub pages_in_use: usize,
    /// Cache rows actually written across live lanes.
    pub rows_used: usize,
    /// Rows reserved by live lanes (`Σ min(pages·page_len, max_seq)`).
    pub rows_reserved: usize,
}

impl PageStats {
    /// Fraction of the pool's pages held by live lanes.
    pub fn occupancy(&self) -> f64 {
        if self.total_pages == 0 {
            return 0.0;
        }
        self.pages_in_use as f64 / self.total_pages as f64
    }

    /// Reserved-but-unwritten fraction: internal fragmentation of the
    /// live reservations (ragged final pages + unspent decode budget).
    pub fn fragmentation(&self) -> f64 {
        if self.rows_reserved == 0 {
            return 0.0;
        }
        1.0 - self.rows_used as f64 / self.rows_reserved as f64
    }
}

/// How a shared-prefix admission bound its lane (PR 6): the engine
/// relays this to the backend (which must treat the shared pages as
/// read-only and skip the resident span's prefill) and into the
/// metrics.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SharedBind {
    /// Prompt rows already cache-resident at bind; chunked prefill
    /// resumes here instead of at row 0.
    pub resident_rows: usize,
    /// Leading page-table entries bound to SHARED physical pages
    /// (refcounted; this lane must never write into them).
    pub shared_pages: usize,
    /// Rows copied into a private fork of a partially-overlapping
    /// shared page (copy-on-write; 0 when the match ended exactly on a
    /// page boundary). The fork is the page-table entry right after the
    /// shared span.
    pub cow_rows: usize,
}

/// A request preempted mid-flight: identifies whose pages were released
/// so the engine can notify the backend and account the event.
#[derive(Debug, Clone, Copy)]
pub struct Preempted {
    /// Lane the request was evicted from.
    pub lane: usize,
    /// The evicted request's id.
    pub id: u64,
}

/// A warm, mid-decode request extracted from a prefill shard for
/// migration to a decode shard (disaggregated serving, PR 7). Carries
/// the full host-side request state needed to rebuild the lane
/// remotely; the KV rows themselves move device-to-device (priced by
/// the modeled backend's migration charge), so only token-level state
/// travels here.
#[derive(Debug, Clone)]
pub struct MigratedLane {
    pub req: GenRequest,
    /// Tokens generated on the source so far (≥ 1 — migration happens
    /// after prefill produced the first token, never before).
    pub tokens: Vec<i32>,
    /// Replay-suppression watermark carried across the move: a request
    /// that migrates while re-generating preempted tokens keeps
    /// suppressing them on the target, so subscriber streams stay
    /// byte-identical.
    pub replayed: usize,
    pub arrived: Instant,
    pub admitted_at: Instant,
    pub first_token_at: Instant,
    /// Source-shard model time at which the lane was handed off (the
    /// source backend's `lane_ready_s`); the target's modeled clock
    /// starts the lane's first decode no earlier. Filled by the engine
    /// — the scheduler has no clock.
    pub ready_s: f64,
    /// The request's SOURCE-shard-local sequence number. The target
    /// assigns its own local seq at import; this one lets a coordinator
    /// move its source-seq→global-seq bookkeeping to the target.
    pub src_seq: u64,
}

/// What one [`Scheduler::ensure_decode_backing`] pass did.
#[derive(Debug, Clone, Default)]
pub struct GrowthReport {
    /// Pages appended to warm lanes' tables this tick.
    pub pages_grown: usize,
    /// Mid-flight `alloc(1)` attempts that found the pool dry (each
    /// triggers one preemption).
    pub grow_failures: usize,
    /// Requests evicted to free pages, in eviction order.
    pub preempted: Vec<Preempted>,
}

/// Recompute state a preempted request carries back through the queue:
/// the tokens it already streamed (suppressed on replay so subscriber
/// streams stay byte-identical) and its original first-token time (so
/// TTFT/decode-time metrics keep measuring the user-visible stream).
#[derive(Debug, Clone, Copy)]
struct Resume {
    emitted: usize,
    first_token_at: Instant,
}

/// A queued request with its submission order and arrival time.
#[derive(Debug, Clone)]
struct Pending {
    req: GenRequest,
    seq: u64,
    arrived: Instant,
    /// Present when this entry is a preempted request awaiting recompute.
    resume: Option<Resume>,
}

/// A request occupying a decode lane — request state AND its cache map
/// (the single occupancy authority).
#[derive(Debug)]
struct InFlight {
    req: GenRequest,
    seq: u64,
    arrived: Instant,
    admitted_at: Instant,
    phase: RequestPhase,
    kv: LaneKv,
    tokens: Vec<i32>,
    first_token_at: Instant,
    /// Tokens already emitted before a preemption (0 for a fresh
    /// admission): regenerated tokens with index < `replayed` are
    /// recompute replays the engine must not re-emit.
    replayed: usize,
    /// Present when admission bound resident shared-prefix pages.
    shared: Option<SharedBind>,
}

impl InFlight {
    fn finish_reason(&self) -> Option<FinishReason> {
        match self.tokens.last() {
            Some(last) if self.req.stop_tokens.contains(last) => Some(FinishReason::Stop),
            Some(_) if self.tokens.len() >= self.req.max_new_tokens => {
                Some(FinishReason::Length)
            }
            _ => None,
        }
    }

    fn into_result(self, now: Instant) -> (Completion, Vec<u32>) {
        let finish_reason = self.finish_reason().unwrap_or(FinishReason::Length);
        ((self.seq, GenResult {
            id: self.req.id,
            tokens: self.tokens,
            ttft: self.first_token_at - self.arrived,
            queue_wait: self.admitted_at - self.arrived,
            decode_time: now - self.first_token_at,
            finish_reason,
        }), self.kv.pages)
    }
}

/// Admission queue + page pool + in-flight state.
#[derive(Debug)]
pub struct Scheduler {
    pool: KvPool,
    queue: VecDeque<Pending>,
    lanes: Vec<Option<InFlight>>,
    /// Gang admission (aligned-only backends): admit only when the pool
    /// is completely free.
    pub gang: bool,
    /// Paged configuration (admission can outnumber the artifact batch).
    paged: bool,
    /// How admission sizes a request's page reservation.
    reserve: ReservationPolicy,
    /// Running sum of the queue's admission reservations (kept on
    /// push/pop so the placement layer's per-tick load reports stay
    /// O(1) instead of rescanning the queue).
    queue_pages: usize,
    /// Shared-prefix index (PR 6): `Some` when prefix sharing is
    /// enabled (paged pools only). Completed prompts register their
    /// page-aligned prefix chunks; admission binds resident chunks
    /// instead of re-prefilling them.
    prefix: Option<PrefixIndex>,
    /// Whether a partially-overlapping shared page may be COW-forked at
    /// bind (copying the overlap rows). Off for backends that cannot
    /// copy pages device-side — the resident span then rounds down to
    /// the last full page boundary.
    partial_cow: bool,
    next_seq: u64,
}

impl Scheduler {
    /// Dense scheduler: one `max_seq`-row page per lane — the PR 2
    /// configuration, reproduced bit-for-bit.
    pub fn new(lanes: usize, prefill_len: usize, max_seq: usize, gang: bool) -> Self {
        assert!(lanes > 0);
        Scheduler {
            pool: KvPool::dense(lanes, prefill_len, max_seq),
            queue: VecDeque::new(),
            lanes: (0..lanes).map(|_| None).collect(),
            gang,
            paged: false,
            reserve: ReservationPolicy::Upfront,
            queue_pages: 0,
            prefix: None,
            partial_cow: true,
            next_seq: 0,
        }
    }

    /// Paged scheduler over `total_pages` shared pages of `page_len`
    /// rows, with up to `max_lanes` logical lanes (a logical lane needs
    /// at least one page, so `max_lanes` beyond `total_pages` buys
    /// nothing). Paged admission requires a per-lane-position backend,
    /// so gang mode does not apply.
    pub fn paged(max_lanes: usize, prefill_len: usize, max_seq: usize,
                 page_len: usize, total_pages: usize) -> Self {
        assert!(max_lanes > 0);
        Scheduler {
            pool: KvPool::paged(prefill_len, max_seq, page_len, total_pages),
            queue: VecDeque::new(),
            lanes: (0..max_lanes.min(total_pages)).map(|_| None).collect(),
            gang: false,
            paged: true,
            reserve: ReservationPolicy::Upfront,
            queue_pages: 0,
            prefix: None,
            partial_cow: true,
            next_seq: 0,
        }
    }

    /// Enable the shared-prefix cache (builder). Coerced OFF on a dense
    /// pool: with one `max_seq`-row page per lane there are no
    /// page-aligned prefix chunks to share.
    pub fn with_prefix_share(mut self, enabled: bool) -> Self {
        self.set_prefix_share(enabled);
        self
    }

    /// `&mut` form of [`Scheduler::with_prefix_share`] for callers that
    /// only hold a constructed scheduler (the engine's builder applies
    /// the flag after capability coercion). Disabling drops the index —
    /// and with it every page pin it held — so flip it before serving,
    /// not mid-flight.
    pub fn set_prefix_share(&mut self, enabled: bool) {
        self.prefix = (enabled && self.paged).then(PrefixIndex::new);
    }

    /// Allow or forbid partial-page COW forks at bind (builder; default
    /// allowed). Backends without a device-side page copy set this
    /// false, rounding resident spans down to full page boundaries.
    pub fn with_partial_cow(mut self, enabled: bool) -> Self {
        self.set_partial_cow(enabled);
        self
    }

    /// `&mut` form of [`Scheduler::with_partial_cow`].
    pub fn set_partial_cow(&mut self, enabled: bool) {
        self.partial_cow = enabled;
    }

    /// Whether shared-prefix admission is enabled.
    pub fn prefix_share(&self) -> bool {
        self.prefix.is_some()
    }

    /// Resident depth (pages) of `prompt`'s prefix in this scheduler's
    /// index, without touching LRU state — the placement layer's
    /// shard-affinity probe.
    pub fn prefix_depth(&self, prompt: &[i32]) -> usize {
        self.prefix
            .as_ref()
            .map(|idx| idx.resident_depth(prompt, self.pool.page_len))
            .unwrap_or(0)
    }

    /// Registered prefix chunks currently resident (one per pinned
    /// page).
    pub fn prefix_entries(&self) -> usize {
        self.prefix.as_ref().map(|idx| idx.len()).unwrap_or(0)
    }

    /// Select the reservation policy (builder; the default is
    /// [`ReservationPolicy::Upfront`], the PR 3 behavior). On a dense
    /// pool `Lazy` is coerced back to `Upfront`: one page backs the
    /// whole `max_seq` row budget, so there is nothing to grow and
    /// nothing preemption could ever reclaim early.
    pub fn with_reserve(mut self, reserve: ReservationPolicy) -> Self {
        self.reserve = if self.paged { reserve } else { ReservationPolicy::Upfront };
        self
    }

    /// The reservation policy in effect.
    pub fn reserve(&self) -> ReservationPolicy {
        self.reserve
    }

    /// Select the pool's page storage codec (builder; default `Fp16`,
    /// which reproduces the PR 7 scheduler bit-for-bit). Coerced back to
    /// `Fp16` on a dense pool: quantization is page-granular, and the
    /// dense layout's one-page-per-lane geometry has no page headers to
    /// amortize — `ServeConfig::validate()` rejects the combination
    /// before it ever reaches here.
    pub fn with_kv_codec(mut self, codec: PageCodec) -> Self {
        self.pool.set_codec(if self.paged { codec } else { PageCodec::Fp16 });
        self
    }

    /// The page storage codec in effect.
    pub fn kv_codec(&self) -> PageCodec {
        self.pool.codec()
    }

    /// Effective storage bytes per cache row (element bytes + amortized
    /// page header) — the metrics surface's honesty figure.
    pub fn kv_bytes_per_row_effective(&self) -> f64 {
        self.pool.bytes_per_row_effective()
    }

    /// The quantization header of a live page — the coordinator-side
    /// mirror the COW fork and the header-consistency tests read.
    pub fn page_header(&self, page: u32) -> PageHeader {
        self.pool.header(page)
    }

    pub fn lanes(&self) -> usize {
        self.lanes.len()
    }

    pub fn prefill_len(&self) -> usize {
        self.pool.prefill_len
    }

    pub fn max_seq(&self) -> usize {
        self.pool.max_seq
    }

    pub fn is_paged(&self) -> bool {
        self.paged
    }

    pub fn page_len(&self) -> usize {
        self.pool.page_len
    }

    /// Pages currently on the free list (the sharded Router's placement
    /// currency: requests go to the shard with the most free pages).
    pub fn free_pages(&self) -> usize {
        self.pool.free_pages()
    }

    /// Total allocatable pages in this scheduler's pool.
    pub fn total_pages(&self) -> usize {
        self.pool.total_pages()
    }

    /// Pages `req` would reserve at ADMISSION under the policy in
    /// effect: the whole-budget reservation up front, or just the
    /// prompt plus one decode slot under lazy growth. This is the unit
    /// the placement layer balances shards by.
    pub fn admission_pages(&self, req: &GenRequest) -> usize {
        self.pool.pages_for(self.admission_rows(req))
    }

    /// Sum of admission reservations still waiting in the queue — the
    /// demand already committed to this scheduler but not yet backed by
    /// pages. `free_pages() - queued_pages()` (saturating) is the honest
    /// free-capacity estimate a placement layer should balance on; raw
    /// free pages would double-book a shard whose queue is deep. O(1):
    /// a running counter maintained on every queue push/pop, so the
    /// per-tick load reports don't rescan a deep queue. (The
    /// reservation policy is fixed at construction — `with_reserve`
    /// runs on an empty queue — so entries' sizes never change.)
    pub fn queued_pages(&self) -> usize {
        self.queue_pages
    }

    /// Ids of the requests currently bound to lanes (in-flight table).
    /// The sharding invariant suite uses this to prove no request ever
    /// appears in two shards' tables at once.
    pub fn inflight_ids(&self) -> Vec<u64> {
        self.lanes.iter().flatten().map(|f| f.req.id).collect()
    }

    /// Ids waiting in the admission queue, FIFO order. Together with
    /// [`Scheduler::inflight_ids`] this is every request the shard is
    /// responsible for — the `verify` fleet predicates prove an id is
    /// never live on two shards at once.
    pub fn queued_ids(&self) -> Vec<u64> {
        self.queue.iter().map(|p| p.req.id).collect()
    }

    /// Owners of `page` in the underlying pool (0 = free). A read-only
    /// passthrough for the shared invariant predicates
    /// ([`crate::verify::invariants`]): refcount consistency is checked
    /// from OUTSIDE the scheduler, against the public referent surface
    /// (lane tables + prefix retains).
    pub fn page_refcount(&self, page: u32) -> u32 {
        self.pool.refcount(page)
    }

    /// Next cache write position of the request bound to `lane`
    /// (`None` when unbound) — the cursor the `cow-write-safety`
    /// predicate checks against page refcounts.
    pub fn lane_pos(&self, lane: usize) -> Option<usize> {
        self.flight(lane).ok().map(|f| f.kv.pos)
    }

    /// Every page the prefix index currently retains (one element per
    /// retained reference). Empty when prefix sharing is off.
    pub fn prefix_retained_pages(&self) -> Vec<u32> {
        self.prefix.as_ref().map(PrefixIndex::retained_pages).unwrap_or_default()
    }

    /// Free-list corruption events the pool absorbed instead of
    /// panicking (release builds only — debug builds panic at the
    /// corrupting call). Snapshot-copied into
    /// [`ServeMetrics::kv_corruption_errors`](super::request::ServeMetrics)
    /// each tick.
    pub fn kv_corruptions(&self) -> usize {
        self.pool.corruption_events()
    }

    /// Pool-wide page accounting (occupancy / fragmentation metrics).
    pub fn page_stats(&self) -> PageStats {
        let mut stats = PageStats {
            total_pages: self.pool.total_pages(),
            pages_in_use: self.pool.pages_in_use(),
            ..PageStats::default()
        };
        for flight in self.lanes.iter().flatten() {
            stats.rows_used += flight.kv.pos;
            stats.rows_reserved += flight.kv.reserved_rows();
        }
        stats
    }

    /// Validate a request against the artifact shapes.
    pub fn validate(&self, req: &GenRequest) -> Result<()> {
        if req.prompt.len() != self.pool.prefill_len {
            return Err(anyhow!(
                "request {}: prompt length {} != artifact prefill length {} \
                 (fixed-shape AOT artifacts)",
                req.id, req.prompt.len(), self.pool.prefill_len
            ));
        }
        if req.max_new_tokens == 0 {
            return Err(anyhow!("request {}: max_new_tokens must be > 0", req.id));
        }
        if self.pool.prefill_len + req.max_new_tokens > self.pool.max_seq {
            return Err(anyhow!(
                "request {}: {} prompt + {} new tokens exceeds max_seq {}",
                req.id, self.pool.prefill_len, req.max_new_tokens, self.pool.max_seq
            ));
        }
        // a reservation larger than the whole pool could NEVER be
        // admitted — head-of-line blocking would spin forever, so refuse
        // it at submission (dense pools always reserve exactly one page,
        // so this only bites undersized paged pools)
        let needed = self.pool.pages_for(self.reserve_rows(req));
        if needed > self.pool.total_pages() {
            return Err(anyhow!(
                "request {}: reservation of {needed} pages exceeds the pool's {} \
                 ({} rows/page)", req.id, self.pool.total_pages(), self.pool.page_len
            ));
        }
        // SLO deadlines ride the request through every queue and clock
        // comparison — non-finite values would make them all vacuous
        if let Err(e) = req.slo.validate() {
            return Err(anyhow!("request {}: {e}", req.id));
        }
        Ok(())
    }

    /// Pages `req` would reserve over its WHOLE life (prompt + budget),
    /// independent of the reservation policy — the figure the sharded
    /// Router checks against per-shard pool capacity to fail over-wide
    /// submissions fast instead of letting them park at the overflow
    /// head forever.
    pub fn reservation_pages(&self, req: &GenRequest) -> usize {
        self.pool.pages_for(self.reserve_rows(req))
    }

    /// Enqueue a validated request; its TTFT clock starts now.
    pub fn submit(&mut self, req: GenRequest) -> Result<()> {
        self.validate(&req)?;
        let seq = self.next_seq;
        self.next_seq += 1;
        let pages = self.admission_pages(&req);
        self.queue_pages += pages;
        self.queue.push_back(Pending { req, seq, arrived: Instant::now(),
                                       resume: None });
        Ok(())
    }

    pub fn queued(&self) -> usize {
        self.queue.len()
    }

    /// Queued entries eligible for cross-shard stealing: requests that
    /// have NEVER been admitted. Preempted entries awaiting recompute
    /// carry a `Resume` watermark — they already streamed tokens from
    /// this shard, so moving them would either replay or drop bytes.
    pub fn stealable_queued(&self) -> usize {
        self.queue.iter().filter(|p| p.resume.is_none()).count()
    }

    /// Remove and return the YOUNGEST stealable queued request (highest
    /// submission order without a resume watermark), with the local
    /// sequence number it held here. The queued-demand counter rolls
    /// back by the same submit-time estimate admission would have
    /// charged. Exactly-once delivery is trivial for the stolen
    /// request: it never bound a lane, so zero events were emitted on
    /// this shard — resubmitting it elsewhere produces its one and only
    /// stream. `None` when nothing is stealable.
    pub fn steal_youngest_queued(&mut self) -> Option<(u64, GenRequest)> {
        let idx = self.queue.iter().rposition(|p| p.resume.is_none())?;
        let p = self.queue.remove(idx)?;
        let estimate = self.pool.pages_for(self.admission_rows(&p.req));
        self.queue_pages = self.queue_pages.saturating_sub(estimate);
        Some((p.seq, p.req))
    }

    /// Sequence number the next submission will receive.
    pub fn seq_watermark(&self) -> u64 {
        self.next_seq
    }

    pub fn active(&self) -> usize {
        self.lanes.iter().filter(|l| l.is_some()).count()
    }

    pub fn has_work(&self) -> bool {
        !self.queue.is_empty() || self.active() > 0
    }

    /// Rows a request reserves over its whole life: prompt + generation
    /// budget. Validation refuses requests whose full need exceeds the
    /// pool under EITHER policy — a lazy request that cannot fit alone
    /// would grow-fail forever with nothing left to preempt.
    fn reserve_rows(&self, req: &GenRequest) -> usize {
        (req.prompt.len() + req.max_new_tokens).min(self.pool.max_seq)
    }

    /// Rows backed at ADMISSION: the full budget up front, or just the
    /// prompt plus one decode slot under lazy growth.
    fn admission_rows(&self, req: &GenRequest) -> usize {
        match self.reserve {
            ReservationPolicy::Upfront => self.reserve_rows(req),
            ReservationPolicy::Lazy => (req.prompt.len() + 1).min(self.pool.max_seq),
        }
    }

    /// The longest shareable resident span for `req`: the matched
    /// full-page chain, plus (when partial COW is allowed) the longest
    /// partial overlap with a resident child chunk. The span is capped
    /// STRICTLY below the prompt — the final token's logits must be
    /// recomputed to produce the request's first generated token, so at
    /// least one row always prefills. Returns the shared pages, the
    /// resident row count, the COW overlap rows (> 0 means the page
    /// after the shared span forks a private copy of that many rows)
    /// and the donor page the fork copies from.
    fn prefix_match(&mut self, req: &GenRequest)
        -> (Vec<u32>, usize, usize, Option<u32>)
    {
        let page_len = self.pool.page_len;
        let Some(idx) = self.prefix.as_mut() else {
            return (Vec::new(), 0, 0, None);
        };
        let hit = idx.lookup(&req.prompt, page_len);
        let mut pages = hit.pages;
        let mut chain = hit.chain;
        let cap = req.prompt.len() - 1;
        if pages.len() * page_len > cap {
            // fully resident prompt: un-share the last page so its rows
            // can be recomputed (or COW-forked) for the final chunk
            pages.pop();
            chain = hit.parent_chain;
        }
        let resident = pages.len() * page_len;
        let mut cow_rows = 0;
        let mut donor = None;
        if self.partial_cow {
            if let Some((page, w)) = idx.partial_overlap(chain, &req.prompt[resident..]) {
                cow_rows = w.min(cap - resident);
                donor = (cow_rows > 0).then_some(page);
            }
        }
        (pages, resident, cow_rows, donor)
    }

    /// Size and stage the head request's bind: shared pages from the
    /// prefix index plus the private pages it must allocate. When the
    /// private need outruns the free list, LRU prefix chains are
    /// evicted first (resident-but-idle cache yields to admission);
    /// `None` means the head still cannot bind — head-of-line blocks.
    fn plan_bind(&mut self, req: &GenRequest)
        -> Option<(Vec<u32>, usize, usize, Option<u32>, usize)>
    {
        loop {
            let (shared, resident_rows, cow_rows, donor) = self.prefix_match(req);
            let logical = self.pool.pages_for(self.admission_rows(req));
            let private = logical - shared.len().min(logical);
            let mut free = self.pool.free_pages();
            if crate::verify::mutants::active(
                crate::verify::mutants::Mutant::StaleFreeReport)
            {
                // injected fault (`verify-mutants`): admission trusts a
                // stale report of one more free page than the pool has
                free += 1;
            }
            if private <= free {
                return Some((shared, resident_rows, cow_rows, donor, private));
            }
            let evicted = match self.prefix.as_mut() {
                Some(idx) => idx.evict_lru(),
                None => Vec::new(),
            };
            if evicted.is_empty() {
                return None;
            }
            // eviction may have dropped pages the match selected, so
            // release and re-match from the fresh index state
            self.pool.release(evicted);
        }
    }

    /// Pick the lanes to admit this iteration and bind them
    /// ([`RequestPhase::Prefilling`] at chunk 0). A request binds only
    /// if its page reservation fits the free list — FIFO with
    /// head-of-line blocking, so admission is refused when PAGES (not
    /// lanes) run out. With prefix sharing enabled, a request whose
    /// prefix is resident binds the shared pages, allocates only its
    /// private tail, and enters with its fill position PAST the shared
    /// span — zero prefill chunks for the resident rows. Returns the
    /// bound lanes; the engine then feeds each prompt through the
    /// policy's prefill path.
    pub fn plan_admissions(&mut self) -> Vec<usize> {
        if self.queue.is_empty() || (self.gang && self.active() > 0) {
            return Vec::new();
        }
        let mut admitted = Vec::new();
        let now = Instant::now();
        let free: Vec<usize> =
            (0..self.lanes.len()).filter(|&l| self.lanes[l].is_none()).collect();
        for lane in free {
            let Some(head) = self.queue.front() else { break };
            let head_req = head.req.clone();
            let Some((shared, resident_rows, cow_rows, donor, private)) =
                self.plan_bind(&head_req)
            else {
                break; // head-of-line blocks: keep FIFO order
            };
            let p = self.queue.pop_front().expect("head checked above");
            // the queued-demand counter tracks the CONSERVATIVE
            // admission estimate recorded at submit time
            let estimate = self.pool.pages_for(self.admission_rows(&p.req));
            self.queue_pages = self.queue_pages.saturating_sub(estimate);
            let shared_count = shared.len();
            let mut table = shared;
            for &page in &table {
                self.pool.retain(page);
            }
            table.extend(self.pool.alloc(private).expect("count checked above"));
            if self.pool.codec() != PageCodec::Fp16 && cow_rows > 0 {
                // the COW fork's destination page holds ONLY the copied
                // common-prefix rows right now: re-quantize them against
                // a fresh scale derived from that narrower population —
                // aliasing the donor's full-page header would put every
                // subsequently scattered row on the wrong grid
                let lo = shared_count * self.pool.page_len;
                let copied = &p.req.prompt[lo..lo + cow_rows];
                self.pool.cow_stamp(
                    donor.expect("cow_rows > 0 implies a donor page"),
                    table[shared_count],
                    sim_rows_amax_k(copied),
                    sim_rows_amax_v(copied),
                );
            }
            let kv = LaneKv::with_resident(p.req.prompt.len(), table,
                                           self.pool.page_len, self.pool.max_seq,
                                           resident_rows + cow_rows)
                .expect("validated request cannot fail to bind");
            // a preempted request re-prefills from chunk 0 but keeps its
            // original first-token clock and emitted-token watermark
            let (first_token_at, replayed) = match p.resume {
                Some(r) => (r.first_token_at, r.emitted),
                // placeholder; overwritten when the prefill completes
                None => (p.arrived, 0),
            };
            let shared_bind = (shared_count > 0 || cow_rows > 0).then_some(SharedBind {
                resident_rows: resident_rows + cow_rows,
                shared_pages: shared_count,
                cow_rows,
            });
            self.lanes[lane] = Some(InFlight {
                req: p.req,
                seq: p.seq,
                arrived: p.arrived,
                admitted_at: now,
                phase: RequestPhase::Prefilling { next_chunk: 0 },
                kv,
                first_token_at,
                tokens: Vec::new(),
                replayed,
                shared: shared_bind,
            });
            admitted.push(lane);
        }
        admitted
    }

    fn flight(&self, lane: usize) -> Result<&InFlight> {
        self.lanes
            .get(lane)
            .and_then(|l| l.as_ref())
            .ok_or_else(|| anyhow!("no request bound to lane {lane}"))
    }

    fn flight_mut(&mut self, lane: usize) -> Result<&mut InFlight> {
        self.lanes
            .get_mut(lane)
            .and_then(|l| l.as_mut())
            .ok_or_else(|| anyhow!("no request bound to lane {lane}"))
    }

    /// Request id bound to `lane`, `None` when unbound. (Returning a
    /// sentinel id here would collide with real ids — 0 is a legal
    /// request id and the open-loop harness indexes per-request arrays
    /// by event id, so the absence must be explicit.)
    pub fn prompt_owner(&self, lane: usize) -> Option<u64> {
        self.flight(lane).ok().map(|f| f.req.id)
    }

    /// Tokens the request on `lane` already streamed before a
    /// preemption: regenerated tokens with index below this watermark
    /// are recompute replays (0 for a fresh admission or unbound lane).
    pub fn replay_watermark(&self, lane: usize) -> usize {
        self.flight(lane).map(|f| f.replayed).unwrap_or(0)
    }

    /// How `lane`'s admission bound shared-prefix state (`None` for a
    /// cold bind or unbound lane). The engine relays this to the
    /// backend before the lane's first chunk and into the metrics.
    pub fn shared_bind(&self, lane: usize) -> Option<SharedBind> {
        self.flight(lane).ok().and_then(|f| f.shared)
    }

    /// Whether any lane is decode-ready (its prompt is cache-resident).
    pub fn has_warm_lane(&self) -> bool {
        self.lanes.iter().flatten().any(|f| f.kv.is_warm())
    }

    /// Tokens the request on `lane` has generated so far.
    pub fn generated(&self, lane: usize) -> usize {
        self.flight(lane).map(|f| f.tokens.len()).unwrap_or(0)
    }

    /// Prompt of the request bound to `lane`.
    pub fn prompt(&self, lane: usize) -> Result<&[i32]> {
        Ok(self.flight(lane)?.req.prompt.as_slice())
    }

    /// Lifecycle phase of the request on `lane` (None when unbound).
    pub fn phase(&self, lane: usize) -> Option<RequestPhase> {
        self.flight(lane).ok().map(|f| f.phase)
    }

    /// Physical pages backing `lane`'s cache (paged backends thread this
    /// through every gather/scatter invocation).
    pub fn page_table(&self, lane: usize) -> Result<&[u32]> {
        Ok(self.flight(lane)?.kv.pages.as_slice())
    }

    /// Lanes with a prompt still streaming in, oldest admission first —
    /// FIFO chunk service completes the head request's prefill (and thus
    /// its first token) soonest.
    pub fn prefilling_lanes(&self) -> Vec<usize> {
        let mut lanes: Vec<usize> = (0..self.lanes.len())
            .filter(|&l| {
                matches!(self.lanes[l].as_ref().map(|f| f.phase),
                         Some(RequestPhase::Prefilling { .. }))
            })
            .collect();
        lanes.sort_by_key(|&l| self.lanes[l].as_ref().map(|f| f.seq).unwrap_or(u64::MAX));
        lanes
    }

    /// The next chunk to feed `lane` under `chunk_len`. The final chunk
    /// of a prompt may be shorter than `chunk_len` (prompt length not a
    /// multiple) or the whole prompt (prompt shorter than one chunk).
    pub fn next_chunk(&self, lane: usize, chunk_len: usize) -> Result<ChunkPlan<'_>> {
        if chunk_len == 0 {
            return Err(anyhow!("chunk_len must be > 0"));
        }
        let flight = self.flight(lane)?;
        let RequestPhase::Prefilling { next_chunk } = flight.phase else {
            return Err(anyhow!("lane {lane} is not prefilling"));
        };
        // chunks resume at the lane's fill position, NOT `next_chunk ·
        // chunk_len`: a shared-prefix bind starts past the resident
        // span, so chunk 0 picks up at the first non-resident row (for
        // a cold lane the two coincide — fills advance `pos` in
        // `chunk_len` steps)
        let start_pos = flight.kv.pos;
        let prompt = flight.req.prompt.as_slice();
        if start_pos >= prompt.len() {
            return Err(anyhow!(
                "lane {lane}: chunk {next_chunk} starts past the prompt \
                 ({start_pos} >= {})", prompt.len()));
        }
        let end = (start_pos + chunk_len).min(prompt.len());
        Ok(ChunkPlan {
            lane,
            start_pos,
            tokens: &prompt[start_pos..end],
            last: end == prompt.len(),
        })
    }

    /// Record a completed prefill chunk of `len` tokens on `lane`. For a
    /// non-final chunk `token` is ignored (the artifact's intermediate
    /// logits are meaningless mid-prompt). The final chunk delivers the
    /// request's first generated token exactly like a blocking prefill —
    /// completing immediately when the budget is one token or the first
    /// token is a stop token.
    pub fn record_chunk(&mut self, lane: usize, len: usize, token: i32)
        -> Result<Option<Completion>>
    {
        let now = Instant::now();
        let page_len = self.pool.page_len;
        // direct field access (not `flight_mut`) so the borrow splits
        // across `lanes` / `pool` / `prefix` for the barrier and the
        // prefix registration below
        let flight = self.lanes.get_mut(lane).and_then(|l| l.as_mut())
            .ok_or_else(|| anyhow!("no request bound to lane {lane}"))?;
        let RequestPhase::Prefilling { next_chunk } = flight.phase else {
            return Err(anyhow!("chunk result for lane {lane} already decoding"));
        };
        let start = flight.kv.pos;
        flight.kv.fill(len)?;
        // write-barrier tripwire: a prefill chunk must land only in
        // PRIVATE pages. Shared pages are skipped at bind (the fill
        // position starts past them), so every touched page is
        // refcount-1 by construction — a higher count here means the
        // planner aliased a live shared page into a write path.
        if len > 0 {
            let quant = self.pool.codec() != PageCodec::Fp16;
            for logical in start / page_len..=(start + len - 1) / page_len {
                let page = flight.kv.pages[logical];
                assert_eq!(self.pool.refcount(page), 1,
                           "prefill chunk wrote into shared KV page {page}");
                if quant {
                    // quantize-on-scatter: re-stamp the page's scale over
                    // every prompt row now resident in it — rows below
                    // `start` landed earlier (prior chunks or the COW
                    // copy) but are prompt rows all the same
                    let lo = logical * page_len;
                    let hi = (start + len).min((logical + 1) * page_len);
                    let rows = &flight.req.prompt[lo..hi];
                    self.pool.stamp_header(page, sim_rows_amax_k(rows),
                                           sim_rows_amax_v(rows));
                }
            }
        }
        if !flight.kv.is_warm() {
            flight.phase = RequestPhase::Prefilling { next_chunk: next_chunk + 1 };
            return Ok(None);
        }
        flight.phase = RequestPhase::Decoding;
        if flight.replayed == 0 {
            // a recompute keeps the original first-token time: the
            // user already saw that token
            flight.first_token_at = now;
        }
        flight.tokens.push(token);
        // register the now-complete prompt's full pages as resident
        // prefix chunks BEFORE any retirement below: the index retains
        // each fresh page, so the prefix stays resident even when the
        // request finishes on its very first token
        if let Some(idx) = self.prefix.as_mut() {
            let fresh = idx.register(&flight.req.prompt, &flight.kv.pages, page_len);
            for page in fresh {
                self.pool.retain(page);
            }
        }
        self.retire_if_finished(lane, now)
    }

    /// Record a blocking prefill's first token: the whole prompt lands
    /// at once and the lane moves straight to decoding; completes
    /// immediately when the budget is one token or the first token is a
    /// stop token.
    pub fn record_prefill(&mut self, lane: usize, token: i32) -> Result<Option<Completion>> {
        let remaining = self.flight(lane)?.kv.prefill_remaining();
        self.record_chunk(lane, remaining, token)
    }

    /// The decode iteration plan: every warm lane with its last token
    /// and write position. Lanes still prefilling are excluded — their
    /// prompts are not yet cache-resident.
    pub fn decode_steps(&self) -> Vec<LaneStep> {
        (0..self.lanes.len())
            .filter_map(|lane| {
                let flight = self.lanes[lane].as_ref()?;
                if !flight.kv.is_warm() {
                    return None;
                }
                Some(LaneStep { lane, token: *flight.tokens.last()?, pos: flight.kv.pos })
            })
            .collect()
    }

    /// The decode plan with page tables attached (paged backends).
    ///
    /// Tables are CLONED into the plan (one small Vec per warm lane per
    /// tick): the engine mutates the scheduler between invocations of a
    /// split tick (token recording can retire lanes and free pages), so
    /// borrowed tables would alias; the copies are noise next to one
    /// artifact execution.
    pub fn paged_decode_steps(&self) -> Vec<PagedStep> {
        self.decode_steps()
            .into_iter()
            .map(|st| {
                let pages = self.lanes[st.lane]
                    .as_ref()
                    .expect("decode step on bound lane")
                    .kv
                    .pages
                    .clone();
                PagedStep { lane: st.lane, token: st.token, pos: st.pos, pages }
            })
            .collect()
    }

    /// Record one decoded token on `lane`, advancing its cache position.
    pub fn record_decode(&mut self, lane: usize, token: i32) -> Result<Option<Completion>> {
        let now = Instant::now();
        let page_len = self.pool.page_len;
        let flight = self.lanes.get_mut(lane).and_then(|l| l.as_mut())
            .ok_or_else(|| anyhow!("no request bound to lane {lane}"))?;
        let write_pos = flight.kv.pos;
        flight.kv.advance()?;
        // write-barrier tripwire (see `record_chunk`): decode rows land
        // past the prompt, and only FULL prompt pages ever register or
        // share, so the write page is always private
        let page = flight.kv.pages[write_pos / page_len];
        assert_eq!(self.pool.refcount(page), 1,
                   "decode wrote into shared KV page {page}");
        if self.pool.codec() != PageCodec::Fp16 {
            // the decode scatter wrote the PREVIOUS token's KV at
            // `write_pos`; re-stamp the page over every row now resident
            // in it — prompt rows below the boundary, generated above
            let prompt_len = flight.req.prompt.len();
            let lo = (write_pos / page_len) * page_len;
            let rows: Vec<i32> = (lo..=write_pos)
                .map(|r| if r < prompt_len { flight.req.prompt[r] }
                         else { flight.tokens[r - prompt_len] })
                .collect();
            self.pool.stamp_header(page, sim_rows_amax_k(&rows),
                                   sim_rows_amax_v(&rows));
        }
        flight.tokens.push(token);
        self.retire_if_finished(lane, now)
    }

    /// Back every warm lane's next cache write with a physical page,
    /// growing tables on demand (lazy reservation). When the pool runs
    /// dry the youngest in-flight request (highest `seq`) is preempted:
    /// its pages are released and it is requeued at the queue HEAD, so
    /// it recomputes as soon as memory frees while older requests keep
    /// their pages (no starvation of the old by the young). A no-op
    /// under [`ReservationPolicy::Upfront`] — reservations are full.
    ///
    /// The engine runs this once per tick before planning the decode
    /// iteration: each warm lane writes exactly one row per tick, so
    /// backing `pos` now covers the whole tick.
    pub fn ensure_decode_backing(&mut self) -> Result<GrowthReport> {
        let mut report = GrowthReport::default();
        if self.reserve != ReservationPolicy::Lazy {
            return Ok(report);
        }
        let mut lane = 0;
        while lane < self.lanes.len() {
            let needs = matches!(&self.lanes[lane],
                                 Some(f) if f.kv.is_warm() && f.kv.needs_growth());
            if !needs {
                lane += 1;
                continue;
            }
            match self.pool.alloc(1) {
                Ok(pages) => {
                    let page = pages[0];
                    let flight = self.lanes[lane].as_mut().expect("lane checked above");
                    if let Err(e) = flight.kv.grow(page) {
                        self.pool.release(pages); // don't leak on refusal
                        return Err(e);
                    }
                    report.pages_grown += 1;
                    lane += 1;
                }
                Err(_) => {
                    // resident-but-idle prefix cache yields to live
                    // execution: evict LRU chains until a page actually
                    // frees (an evicted page still held by a lane frees
                    // nothing), and preempt only once the index is dry
                    let evicted = self.prefix.as_mut()
                        .map(|idx| idx.evict_lru())
                        .unwrap_or_default();
                    if !evicted.is_empty() {
                        self.pool.release(evicted);
                        continue; // retry the same lane
                    }
                    report.grow_failures += 1;
                    let victim = self.preempt_youngest().ok_or_else(|| anyhow!(
                        "KV pool dry with nothing to preempt: a validated \
                         request's full reservation fits the pool, so this \
                         means the allocator leaked pages"))?;
                    let evicted_self = victim.lane == lane;
                    report.preempted.push(victim);
                    if evicted_self {
                        // the grower itself was youngest: it is requeued
                        // for recompute; move on
                        lane += 1;
                    }
                    // otherwise retry the same lane against the freed pages
                }
            }
        }
        Ok(report)
    }

    /// Evict the youngest in-flight request (highest `seq`): release its
    /// pages and requeue it at the queue head carrying its recompute
    /// state. Returns `None` when no request is in flight.
    fn preempt_youngest(&mut self) -> Option<Preempted> {
        let lane = (0..self.lanes.len())
            .filter(|&l| self.lanes[l].is_some())
            .max_by_key(|&l| self.lanes[l].as_ref().map(|f| f.seq))?;
        let flight = self.lanes[lane].take().expect("selected occupied lane");
        let id = flight.req.id;
        self.pool.release(flight.kv.pages);
        // a request preempted DURING its own replay keeps the larger
        // watermark: those tokens were emitted in the original run
        let emitted = flight.tokens.len().max(flight.replayed);
        let resume = (emitted > 0).then_some(Resume {
            emitted,
            first_token_at: flight.first_token_at,
        });
        let requeued_pages = self.admission_pages(&flight.req);
        self.queue_pages += requeued_pages;
        self.queue.push_front(Pending {
            req: flight.req,
            seq: flight.seq,
            arrived: flight.arrived,
            resume,
        });
        Some(Preempted { lane, id })
    }

    /// Extract every DECODING-phase request for migration to another
    /// shard, releasing their pages here (refcount-aware: a shared
    /// prefix page just drops this lane's claim — the prefix index
    /// keeps its own retains, so the prefix stays resident on this
    /// shard for future admissions). Prefilling lanes stay put: their
    /// chunk state is mid-stream on this shard's prefill engine.
    ///
    /// Returns `(lane, state)` pairs; the engine layer notifies the
    /// backend per lane and stamps each `ready_s`.
    pub fn take_migratable(&mut self) -> Vec<(usize, MigratedLane)> {
        let mut out = Vec::new();
        for lane in 0..self.lanes.len() {
            let warm = matches!(&self.lanes[lane],
                                Some(f) if matches!(f.phase, RequestPhase::Decoding));
            if !warm {
                continue;
            }
            let flight = self.lanes[lane].take().expect("lane checked above");
            if !crate::verify::mutants::active(
                crate::verify::mutants::Mutant::DropDonorRelease)
            {
                // injected fault (`verify-mutants`) when skipped: the
                // donor forgets the migrated lane's pages — a leak the
                // model checker must pin on this shard
                self.pool.release(flight.kv.pages);
            }
            out.push((lane, MigratedLane {
                req: flight.req,
                tokens: flight.tokens,
                replayed: flight.replayed,
                arrived: flight.arrived,
                admitted_at: flight.admitted_at,
                first_token_at: flight.first_token_at,
                ready_s: 0.0,
                src_seq: flight.seq,
            }));
        }
        out
    }

    /// Pages an [`Scheduler::import_lane`] of `m` would allocate: the
    /// full span under up-front reservation, the written rows plus one
    /// decode slot under lazy (growth takes over from there). The
    /// placement layer checks this against a target's free pages before
    /// migrating.
    pub fn import_pages(&self, m: &MigratedLane) -> usize {
        let rows_written = m.req.prompt.len() + m.tokens.len() - 1;
        let span = match self.reserve {
            ReservationPolicy::Upfront =>
                (m.req.prompt.len() + m.req.max_new_tokens).min(self.pool.max_seq),
            ReservationPolicy::Lazy => (rows_written + 1).min(self.pool.max_seq),
        };
        self.pool.pages_for(span)
    }

    /// Rebuild a migrated request on this scheduler: allocate fresh
    /// PRIVATE pages for its written rows (plus its decode reservation)
    /// and bind a free lane directly in [`RequestPhase::Decoding`].
    ///
    /// Shared-prefix state does NOT travel — the migrated copy is
    /// private (copy-on-migrate) and this scheduler's prefix index is
    /// untouched. Under lazy reservation a later preemption of this
    /// lane requeues it HERE, so its recompute prefills locally on this
    /// shard (documented in DESIGN.md §13).
    ///
    /// Returns the lane bound; the engine layer hands the same pages to
    /// the backend's `import_lane`.
    pub fn import_lane(&mut self, m: &MigratedLane) -> Result<usize> {
        if !self.paged {
            return Err(anyhow!("lane migration requires a paged pool"));
        }
        if m.tokens.is_empty() {
            return Err(anyhow!(
                "migrated request {} has no first token", m.req.id));
        }
        let lane = (0..self.lanes.len())
            .find(|&l| self.lanes[l].is_none())
            .ok_or_else(|| anyhow!("no free lane to import request {} into",
                                   m.req.id))?;
        let pages = self.pool.alloc(self.import_pages(m))?;
        let decoded_rows = m.tokens.len() - 1;
        let kv = match LaneKv::imported(m.req.prompt.len(), decoded_rows,
                                        pages.clone(), self.pool.page_len,
                                        self.pool.max_seq) {
            Ok(kv) => kv,
            Err(e) => {
                // the flight was never bound: hand the pages straight back
                self.pool.release(pages);
                return Err(e);
            }
        };
        if self.pool.codec() != PageCodec::Fp16 {
            // the migration DMA carries the quantized page bytes AND
            // their headers: re-stamp each imported page over the rows
            // it holds (trailing reservation-only pages stay identity
            // until their first decode write re-stamps them)
            let prompt_len = m.req.prompt.len();
            let rows_written = prompt_len + decoded_rows;
            for (logical, &page) in pages.iter().enumerate() {
                let lo = logical * self.pool.page_len;
                if lo >= rows_written {
                    break;
                }
                let hi = rows_written.min(lo + self.pool.page_len);
                let rows: Vec<i32> = (lo..hi)
                    .map(|r| if r < prompt_len { m.req.prompt[r] }
                             else { m.tokens[r - prompt_len] })
                    .collect();
                self.pool.stamp_header(page, sim_rows_amax_k(&rows),
                                       sim_rows_amax_v(&rows));
            }
        }
        self.lanes[lane] = Some(InFlight {
            req: m.req.clone(),
            seq: self.next_seq,
            arrived: m.arrived,
            admitted_at: m.admitted_at,
            phase: RequestPhase::Decoding,
            kv,
            tokens: m.tokens.clone(),
            first_token_at: m.first_token_at,
            replayed: m.replayed,
            shared: None,
        });
        self.next_seq += 1;
        Ok(lane)
    }

    /// Drop the request on `lane` entirely, releasing its pages — the
    /// rollback path when a backend refuses an import the scheduler
    /// already bound.
    pub fn abort_lane(&mut self, lane: usize) {
        if let Some(flight) = self.lanes.get_mut(lane).and_then(|l| l.take()) {
            self.pool.release(flight.kv.pages);
        }
    }

    fn retire_if_finished(&mut self, lane: usize, now: Instant) -> Result<Option<Completion>> {
        let flight = self.lanes[lane].as_ref().expect("lane checked by caller");
        // under lazy reservation a lane whose backing lags its budget is
        // grown, not retired: exhaustion is only the max_seq hard cap
        let exhausted = match self.reserve {
            ReservationPolicy::Upfront => flight.kv.remaining() == 0,
            ReservationPolicy::Lazy => flight.kv.pos >= self.pool.max_seq,
        };
        if flight.finish_reason().is_none() && !exhausted {
            return Ok(None);
        }
        let flight = self.lanes[lane].take().expect("lane occupied");
        let (completion, pages) = flight.into_result(now);
        self.pool.release(pages);
        Ok(Some(completion))
    }

    /// Drop everything — queued and in-flight — after a backend error so
    /// the engine thread can keep serving subsequent requests.
    pub fn abort_all(&mut self) {
        self.queue.clear();
        self.queue_pages = 0;
        for slot in &mut self.lanes {
            if let Some(flight) = slot.take() {
                self.pool.release(flight.kv.pages);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sched() -> Scheduler {
        Scheduler::new(2, 4, 12, false)
    }

    fn req(id: u64, new: usize) -> GenRequest {
        GenRequest::new(id, vec![id as i32; 4], new)
    }

    #[test]
    fn validates_prompt_shape() {
        let mut s = sched();
        assert!(s.submit(GenRequest::new(1, vec![0; 3], 2)).is_err());
        assert!(s.submit(GenRequest::new(1, vec![0; 4], 0)).is_err());
        assert!(s.submit(GenRequest::new(1, vec![0; 4], 9)).is_err());
        assert!(s.submit(req(1, 8)).is_ok());
    }

    #[test]
    fn admits_up_to_pool_capacity() {
        let mut s = sched();
        for i in 0..3 {
            s.submit(req(i, 2)).unwrap();
        }
        let admitted = s.plan_admissions();
        assert_eq!(admitted, vec![0, 1]);
        assert_eq!(s.queued(), 1);
        assert_eq!(s.active(), 2);
        assert!(s.plan_admissions().is_empty());
    }

    #[test]
    fn lane_frees_and_backfills() {
        let mut s = sched();
        s.submit(req(1, 1)).unwrap();
        s.submit(req(2, 3)).unwrap();
        s.submit(req(3, 2)).unwrap();
        let admitted = s.plan_admissions();
        assert_eq!(admitted.len(), 2);
        // request 1 has a 1-token budget: finishes at prefill
        let (seq, done) = s.record_prefill(0, 7).unwrap().unwrap();
        assert_eq!(seq, 0);
        assert_eq!(done.id, 1);
        assert_eq!(done.finish_reason, FinishReason::Length);
        assert!(s.record_prefill(1, 8).unwrap().is_none());
        // freed lane 0 is immediately backfillable
        assert_eq!(s.plan_admissions(), vec![0]);
    }

    #[test]
    fn stop_token_retires_lane() {
        let mut s = sched();
        s.submit(req(1, 8).with_stop_tokens(vec![42])).unwrap();
        s.plan_admissions();
        assert!(s.record_prefill(0, 5).unwrap().is_none());
        let (_, done) = s.record_decode(0, 42).unwrap().unwrap();
        assert_eq!(done.finish_reason, FinishReason::Stop);
        assert_eq!(done.tokens, vec![5, 42]);
        assert_eq!(s.active(), 0);
    }

    #[test]
    fn gang_mode_waits_for_empty_pool() {
        let mut s = Scheduler::new(2, 4, 12, true);
        s.submit(req(1, 2)).unwrap();
        s.submit(req(2, 2)).unwrap();
        s.submit(req(3, 2)).unwrap();
        assert_eq!(s.plan_admissions().len(), 2);
        s.record_prefill(0, 1).unwrap();
        s.record_prefill(1, 1).unwrap();
        // one lane finishes; gang mode must NOT backfill yet
        let done = s.record_decode(0, 1).unwrap();
        assert!(done.is_some());
        assert!(s.plan_admissions().is_empty());
        let done = s.record_decode(1, 1).unwrap();
        assert!(done.is_some());
        assert_eq!(s.plan_admissions(), vec![0]);
    }

    #[test]
    fn decode_steps_cover_exactly_active_lanes() {
        let mut s = sched();
        s.submit(req(1, 4)).unwrap();
        s.submit(req(2, 4)).unwrap();
        s.plan_admissions();
        s.record_prefill(0, 1).unwrap();
        s.record_prefill(1, 2).unwrap();
        let steps = s.decode_steps();
        assert_eq!(steps.len(), 2);
        assert_eq!(steps[0].pos, 4);
        assert_eq!(steps[0].token, 1);
        s.record_decode(0, 9).unwrap();
        let steps = s.decode_steps();
        assert_eq!(steps[0].pos, 5);
        assert_eq!(steps[0].token, 9);
    }

    #[test]
    fn kv_exhaustion_forces_length_finish() {
        // max_seq 6, prefill 4 → at most 2 generated tokens fit
        let mut s = Scheduler::new(1, 4, 6, false);
        s.submit(GenRequest::new(1, vec![0; 4], 2)).unwrap();
        s.plan_admissions();
        assert!(s.record_prefill(0, 1).unwrap().is_none());
        let (_, done) = s.record_decode(0, 2).unwrap().unwrap();
        assert_eq!(done.tokens.len(), 2);
        assert_eq!(done.finish_reason, FinishReason::Length);
    }

    #[test]
    fn chunked_prefill_state_machine() {
        let mut s = sched();
        s.submit(req(1, 4)).unwrap();
        s.submit(req(2, 4)).unwrap();
        s.plan_admissions();
        assert_eq!(s.prefilling_lanes(), vec![0, 1]);
        assert_eq!(s.phase(0), Some(RequestPhase::Prefilling { next_chunk: 0 }));
        // prefilling lanes do not decode
        assert!(s.decode_steps().is_empty());

        // 3-token chunks over a 4-token prompt: chunks of 3 and 1
        let plan = s.next_chunk(0, 3).unwrap();
        assert_eq!((plan.start_pos, plan.tokens.len(), plan.last), (0, 3, false));
        assert!(s.record_chunk(0, 3, 0).unwrap().is_none());
        assert_eq!(s.phase(0), Some(RequestPhase::Prefilling { next_chunk: 1 }));
        let plan = s.next_chunk(0, 3).unwrap();
        assert_eq!((plan.start_pos, plan.tokens.len(), plan.last), (3, 1, true));
        assert!(s.record_chunk(0, 1, 9).unwrap().is_none());
        assert_eq!(s.phase(0), Some(RequestPhase::Decoding));
        // lane 0 decodes while lane 1 is still prefilling
        assert_eq!(s.prefilling_lanes(), vec![1]);
        let steps = s.decode_steps();
        assert_eq!(steps.len(), 1);
        assert_eq!((steps[0].lane, steps[0].token, steps[0].pos), (0, 9, 4));

        // prompt shorter than one chunk: a single final chunk
        let plan = s.next_chunk(1, 64).unwrap();
        assert_eq!((plan.start_pos, plan.tokens.len(), plan.last), (0, 4, true));
        assert!(s.record_chunk(1, 4, 7).unwrap().is_none());
        assert_eq!(s.decode_steps().len(), 2);
        // chunk ops on a decoding lane are an error
        assert!(s.next_chunk(1, 4).is_err());
        assert!(s.record_chunk(1, 1, 0).is_err());
    }

    #[test]
    fn chunked_first_token_can_retire_immediately() {
        let mut s = sched();
        s.submit(req(1, 1)).unwrap(); // 1-token budget
        s.submit(req(2, 8).with_stop_tokens(vec![42])).unwrap();
        s.plan_admissions();
        // budget-1 request retires on its final chunk
        assert!(s.record_chunk(0, 2, 5).unwrap().is_none());
        let (_, done) = s.record_chunk(0, 2, 5).unwrap().unwrap();
        assert_eq!(done.finish_reason, FinishReason::Length);
        assert_eq!(done.tokens, vec![5]);
        // stop token as the first generated token retires too
        assert!(s.record_chunk(1, 2, 0).unwrap().is_none());
        let (_, done) = s.record_chunk(1, 2, 42).unwrap().unwrap();
        assert_eq!(done.finish_reason, FinishReason::Stop);
        assert_eq!(s.active(), 0);
    }

    #[test]
    fn freed_lane_backfills_while_neighbor_half_prefilled() {
        let mut s = sched();
        s.submit(req(1, 1)).unwrap();
        s.submit(req(2, 4)).unwrap();
        s.submit(req(3, 2)).unwrap();
        s.plan_admissions();
        // lane 1 gets half its prompt; lane 0 completes and retires
        assert!(s.record_chunk(1, 2, 0).unwrap().is_none());
        assert!(s.record_prefill(0, 7).unwrap().is_some());
        // the freed lane backfills while lane 1 is still mid-prompt
        assert_eq!(s.plan_admissions(), vec![0]);
        assert_eq!(s.prefilling_lanes(), vec![1, 0]); // oldest (seq) first
        assert_eq!(s.phase(0), Some(RequestPhase::Prefilling { next_chunk: 0 }));
        assert_eq!(s.phase(1), Some(RequestPhase::Prefilling { next_chunk: 1 }));
    }

    #[test]
    fn abort_clears_everything() {
        let mut s = sched();
        s.submit(req(1, 4)).unwrap();
        s.submit(req(2, 4)).unwrap();
        s.submit(req(3, 4)).unwrap();
        s.plan_admissions();
        assert_eq!(s.queued_pages(), 1, "one request left queued (1 dense page)");
        s.abort_all();
        assert!(!s.has_work());
        assert_eq!(s.queued(), 0);
        assert_eq!(s.queued_pages(), 0, "abort must zero the queued-demand counter");
        assert_eq!(s.active(), 0);
        assert_eq!(s.page_stats().pages_in_use, 0, "abort leaked pages");
    }

    // -- paged admission ---------------------------------------------------

    /// Paged pool: prompt 4, page_len 8 → a request of budget b reserves
    /// ceil((4 + b) / 8) pages.
    fn paged_sched(max_lanes: usize, pages: usize) -> Scheduler {
        Scheduler::paged(max_lanes, 4, 32, 8, pages)
    }

    #[test]
    fn paged_admission_outnumbers_artifact_batch() {
        // 6 short requests (1 page each) fit 6 logical lanes on the
        // memory of 1.5 dense max_seq rows
        let mut s = paged_sched(8, 6);
        for i in 0..8 {
            s.submit(req(i, 2)).unwrap();
        }
        let admitted = s.plan_admissions();
        assert_eq!(admitted.len(), 6, "admission should be page-bound");
        assert_eq!(s.page_stats().pages_in_use, 6);
        assert_eq!(s.queued(), 2);
    }

    #[test]
    fn paged_admission_refused_on_page_exhaustion_not_lanes() {
        let mut s = paged_sched(4, 3);
        // budget 12 → 16 rows → 2 pages each
        s.submit(req(1, 12)).unwrap();
        s.submit(req(2, 12)).unwrap();
        let admitted = s.plan_admissions();
        assert_eq!(admitted.len(), 1, "3 free lanes but only 1 free page");
        assert_eq!(s.queued(), 1);
        assert_eq!(s.page_stats().pages_in_use, 2);
        // retiring the first frees its pages and unblocks the head
        s.record_prefill(0, 7).unwrap();
        while s.record_decode(0, 3).unwrap().is_none() {}
        assert_eq!(s.active(), 0);
        assert_eq!(s.plan_admissions().len(), 1);
    }

    #[test]
    fn paged_validate_rejects_impossible_reservation() {
        // 2 pages of 8 rows: a 3-page reservation could never admit and
        // would head-of-line-block the queue forever — refuse at submit
        let mut s = paged_sched(2, 2);
        assert!(s.submit(req(1, 20)).is_err()); // 4 + 20 rows → 3 pages
        assert!(s.submit(req(2, 12)).is_ok()); // 4 + 12 rows → 2 pages
    }

    #[test]
    fn paged_head_of_line_blocks_fifo() {
        let mut s = paged_sched(4, 3);
        s.submit(req(1, 12)).unwrap(); // 2 pages
        s.submit(req(2, 12)).unwrap(); // 2 pages — blocks (1 free)
        s.submit(req(3, 2)).unwrap();  // 1 page — would fit, must NOT jump
        let admitted = s.plan_admissions();
        assert_eq!(admitted.len(), 1);
        assert_eq!(s.prompt_owner(0), Some(1));
        assert_eq!(s.prompt_owner(1), None, "unbound lane must not report an id");
        assert_eq!(s.queued(), 2, "short request must not overtake the head");
    }

    /// Pages held by live lanes, counted INDEPENDENTLY of the
    /// allocator's own bookkeeping (sums the page tables).
    fn lane_held_pages(s: &Scheduler) -> usize {
        (0..s.lanes()).map(|l| s.page_table(l).map(|p| p.len()).unwrap_or(0)).sum()
    }

    #[test]
    fn paged_release_then_rebind_reclaims_pages() {
        let mut s = paged_sched(2, 2);
        for i in 0..5 {
            s.submit(req(i, 2)).unwrap();
        }
        let mut served = 0;
        while s.has_work() {
            for lane in s.plan_admissions() {
                s.record_prefill(lane, 1).unwrap();
            }
            let steps = s.decode_steps();
            for st in steps {
                if s.record_decode(st.lane, 3).unwrap().is_some() {
                    served += 1;
                }
            }
            // the allocator's in-use count must equal what the live
            // lanes actually hold — a release path that leaked (or
            // double-freed) would desync the two
            assert_eq!(s.page_stats().pages_in_use, lane_held_pages(&s),
                       "page accounting desynced from lane tables");
        }
        assert_eq!(served, 5);
        assert_eq!(s.page_stats().pages_in_use, 0);
        assert_eq!(lane_held_pages(&s), 0);
    }

    #[test]
    fn paged_ragged_final_page_with_chunked_prefill() {
        // prompt 4 + budget 3 = 7 rows on 8-row pages: 1 page, ragged
        let mut s = paged_sched(2, 4);
        s.submit(req(1, 3)).unwrap();
        s.plan_admissions();
        assert_eq!(s.page_table(0).unwrap().len(), 1);
        // chunk the prompt in 3+1 while tracking the phase machine
        assert!(s.record_chunk(0, 3, 0).unwrap().is_none());
        assert_eq!(s.phase(0), Some(RequestPhase::Prefilling { next_chunk: 1 }));
        assert!(s.record_chunk(0, 1, 9).unwrap().is_none());
        assert_eq!(s.phase(0), Some(RequestPhase::Decoding));
        let stats = s.page_stats();
        assert_eq!(stats.rows_reserved, 8);
        assert_eq!(stats.rows_used, 4);
        assert!(stats.fragmentation() > 0.0);
        s.record_decode(0, 1).unwrap();
        let (_, done) = s.record_decode(0, 2).unwrap().unwrap();
        assert_eq!(done.tokens.len(), 3);
        assert_eq!(s.page_stats().pages_in_use, 0);
    }

    #[test]
    fn paged_decode_steps_carry_page_tables() {
        let mut s = paged_sched(2, 4);
        s.submit(req(1, 12)).unwrap(); // 2 pages
        s.plan_admissions();
        s.record_prefill(0, 7).unwrap();
        let steps = s.paged_decode_steps();
        assert_eq!(steps.len(), 1);
        assert_eq!(steps[0].pages.len(), 2);
        assert_eq!(steps[0].pos, 4);
        assert_eq!(steps[0].token, 7);
    }

    // -- lazy reservation + preempt-and-recompute --------------------------

    /// Lazy paged pool: prompt 4 over 4-row pages, so admission backs
    /// 2 pages (prompt + one decode slot) regardless of budget.
    fn lazy_sched(max_lanes: usize, pages: usize) -> Scheduler {
        Scheduler::paged(max_lanes, 4, 32, 4, pages)
            .with_reserve(ReservationPolicy::Lazy)
    }

    #[test]
    fn lazy_admission_backs_prompt_plus_one_slot() {
        // budget 12 would reserve 4 pages up front; lazily it binds 2
        let mut s = lazy_sched(4, 4);
        s.submit(req(1, 12)).unwrap();
        s.submit(req(2, 12)).unwrap();
        let admitted = s.plan_admissions();
        assert_eq!(admitted.len(), 2,
                   "lazy admission must bind by prompt pages, not budget");
        assert_eq!(s.page_table(0).unwrap().len(), 2);
        assert_eq!(s.page_stats().pages_in_use, 4);
        // upfront on the same geometry admits only one
        let mut up = Scheduler::paged(4, 4, 32, 4, 4);
        up.submit(req(1, 12)).unwrap();
        up.submit(req(2, 12)).unwrap();
        assert_eq!(up.plan_admissions().len(), 1);
    }

    #[test]
    fn lazy_growth_allocates_as_decode_crosses_pages() {
        let mut s = lazy_sched(1, 8);
        s.submit(req(1, 12)).unwrap(); // full need: 16 rows = 4 pages
        s.plan_admissions();
        s.record_prefill(0, 7).unwrap();
        let mut grown = 0;
        loop {
            let g = s.ensure_decode_backing().unwrap();
            grown += g.pages_grown;
            assert!(g.preempted.is_empty(), "ample pool must not preempt");
            let steps = s.decode_steps();
            if steps.is_empty() {
                break;
            }
            if s.record_decode(0, 3).unwrap().is_some() {
                break;
            }
        }
        // rows 4..16 written: pages 2 and 3 appended on demand
        assert_eq!(grown, 2);
        assert_eq!(s.page_stats().pages_in_use, 0, "retire released grown pages");
    }

    #[test]
    fn dry_pool_preempts_youngest_and_requeues_at_head() {
        // 4 pages: two lazy requests bind 2 pages each; the first growth
        // attempt finds the pool dry and must evict seq 1 (the youngest)
        let mut s = lazy_sched(2, 4);
        s.submit(req(1, 12)).unwrap();
        s.submit(req(2, 12)).unwrap();
        assert_eq!(s.plan_admissions().len(), 2);
        s.record_prefill(0, 7).unwrap();
        s.record_prefill(1, 8).unwrap();
        // four decode rounds take both lanes from pos 4 to pos 8 — the
        // edge of their two 4-row pages — without any growth
        for _ in 0..4 {
            let g = s.ensure_decode_backing().unwrap();
            assert_eq!((g.pages_grown, g.preempted.len()), (0, 0));
            for st in s.decode_steps() {
                s.record_decode(st.lane, 3).unwrap();
            }
        }
        // both lanes now need a page and the pool is dry: the youngest
        // (seq 1 = request 2) is evicted and its pages feed lane 0
        let g = s.ensure_decode_backing().unwrap();
        assert_eq!(g.grow_failures, 1);
        assert_eq!(g.preempted.len(), 1, "dry pool must preempt");
        assert_eq!((g.preempted[0].lane, g.preempted[0].id), (1, 2),
                   "victim must be the YOUNGEST request");
        assert_eq!(g.pages_grown, 1, "freed pages must satisfy the grower");
        assert_eq!(s.active(), 1);
        assert_eq!(s.queued(), 1, "victim requeued");
        assert_eq!(s.queued_pages(), 2,
                   "requeued victim must re-enter the queued-demand counter \
                    (lazy: prompt 4 + 1 slot on 4-row pages = 2)");
        // drive the survivor to completion; its pages free and the
        // victim re-admits from the queue head carrying its watermark
        while s.active() > 0 {
            s.ensure_decode_backing().unwrap();
            for st in s.decode_steps() {
                s.record_decode(st.lane, 3).unwrap();
            }
        }
        let lanes = s.plan_admissions();
        assert_eq!(lanes.len(), 1);
        assert_eq!(s.prompt_owner(lanes[0]), Some(2));
        assert_eq!(s.replay_watermark(lanes[0]), 5,
                   "recompute must carry the emitted-token watermark");
    }

    #[test]
    fn dense_scheduler_coerces_lazy_to_upfront() {
        let s = Scheduler::new(2, 4, 12, false).with_reserve(ReservationPolicy::Lazy);
        assert_eq!(s.reserve(), ReservationPolicy::Upfront);
        let s = Scheduler::paged(2, 4, 32, 8, 4).with_reserve(ReservationPolicy::Lazy);
        assert_eq!(s.reserve(), ReservationPolicy::Lazy);
    }

    #[test]
    fn placement_accessors_track_free_queued_and_inflight() {
        let mut s = paged_sched(4, 6); // 8-row pages, prompt 4
        assert_eq!(s.free_pages(), 6);
        assert_eq!(s.total_pages(), 6);
        assert_eq!(s.queued_pages(), 0);
        assert!(s.inflight_ids().is_empty());
        s.submit(req(7, 12)).unwrap(); // 16 rows → 2 pages
        s.submit(req(8, 2)).unwrap(); // 6 rows → 1 page
        assert_eq!(s.queued_pages(), 3, "queued demand must sum admission pages");
        assert_eq!(s.free_pages(), 6, "queueing allocates nothing");
        s.plan_admissions();
        assert_eq!(s.queued_pages(), 0);
        assert_eq!(s.free_pages(), 3);
        let mut ids = s.inflight_ids();
        ids.sort_unstable();
        assert_eq!(ids, vec![7, 8]);
        // lazy admission sizes the reservation differently
        let lazy = paged_sched(4, 6).with_reserve(ReservationPolicy::Lazy);
        assert_eq!(lazy.admission_pages(&req(7, 12)), 1, "prompt 4 + 1 slot");
        let up = paged_sched(4, 6);
        assert_eq!(up.admission_pages(&req(7, 12)), 2);
    }

    // -- shared-prefix admission (PR 6) ------------------------------------

    /// Prefix-sharing pool: 8-token prompts over 4-row pages → two full
    /// prompt pages per request, so a warm prompt registers 2 chunks.
    fn prefix_sched(max_lanes: usize, pages: usize) -> Scheduler {
        Scheduler::paged(max_lanes, 8, 32, 4, pages).with_prefix_share(true)
    }

    fn shared_req(id: u64, new: usize) -> GenRequest {
        GenRequest::new(id, (0..8).collect(), new)
    }

    #[test]
    fn shared_admission_skips_resident_span_with_cow_fork() {
        let mut s = prefix_sched(2, 8);
        s.submit(shared_req(1, 2)).unwrap();
        assert_eq!(s.plan_admissions(), vec![0]);
        assert_eq!(s.shared_bind(0), None, "cold index: nothing to share");
        // chunk the first prompt in: pos-based plans match chunk·len
        let plan = s.next_chunk(0, 4).unwrap();
        assert_eq!((plan.start_pos, plan.tokens.len(), plan.last), (0, 4, false));
        s.record_chunk(0, 4, 0).unwrap();
        s.record_chunk(0, 4, 9).unwrap();
        assert_eq!(s.prefix_entries(), 2, "warm prompt registers its full pages");
        assert_eq!(s.prefix_depth(&shared_req(2, 2).prompt), 2);
        // the second, identical prompt shares page 0 and COW-forks page
        // 1 (row 7 must be recomputed for the first token's logits)
        s.submit(shared_req(2, 2)).unwrap();
        assert_eq!(s.plan_admissions(), vec![1]);
        assert_eq!(s.shared_bind(1),
                   Some(SharedBind { resident_rows: 7, shared_pages: 1,
                                     cow_rows: 3 }));
        let plan = s.next_chunk(1, 4).unwrap();
        assert_eq!((plan.start_pos, plan.tokens.len(), plan.last), (7, 1, true),
                   "prefill must resume at the first non-resident row");
        assert!(s.record_chunk(1, 1, 5).unwrap().is_none());
        assert_eq!(s.phase(1), Some(RequestPhase::Decoding));
        // page accounting: lane 0 holds 3 pages (prompt 8 + budget 2 →
        // 10 rows), lane 1 re-uses one of them + 2 private
        assert_eq!(s.page_table(1).unwrap().len(), 3);
        assert_eq!(s.page_table(1).unwrap()[0], s.page_table(0).unwrap()[0],
                   "leading table entry must alias the donor's page");
        assert_eq!(s.page_stats().pages_in_use, 5);
        // retire both; the registered pages stay resident via the index
        while s.active() > 0 {
            for st in s.decode_steps() {
                s.record_decode(st.lane, 3).unwrap();
            }
        }
        assert_eq!(s.prefix_entries(), 2);
        assert_eq!(s.page_stats().pages_in_use, 2,
                   "index-pinned pages survive their registrants");
    }

    #[test]
    fn shared_admission_resumes_at_page_boundary_without_partial_cow() {
        // Upfront and Lazy: without partial COW the resident span
        // rounds down to the last full page boundary and chunked
        // prefill resumes exactly there (mid-prompt)
        for reserve in [ReservationPolicy::Upfront, ReservationPolicy::Lazy] {
            let mut s = prefix_sched(2, 8)
                .with_partial_cow(false)
                .with_reserve(reserve);
            s.submit(shared_req(1, 2)).unwrap();
            s.plan_admissions();
            s.record_prefill(0, 9).unwrap();
            s.submit(shared_req(2, 2)).unwrap();
            assert_eq!(s.plan_admissions(), vec![1]);
            assert_eq!(s.shared_bind(1),
                       Some(SharedBind { resident_rows: 4, shared_pages: 1,
                                         cow_rows: 0 }),
                       "no partial COW: span rounds down to one full page");
            let plan = s.next_chunk(1, 4).unwrap();
            assert_eq!((plan.start_pos, plan.tokens.len(), plan.last),
                       (4, 4, true),
                       "chunk 0 must start at the page-boundary resume point");
            assert!(s.record_chunk(1, 4, 5).unwrap().is_none());
            assert_eq!(s.phase(1), Some(RequestPhase::Decoding));
        }
    }

    #[test]
    fn preempting_prefix_sharer_keeps_shared_pages_resident() {
        // lazy pool of 6: request 1 binds 3 pages, decodes with growth;
        // request 2 shared-binds (1 shared + 2 private) mid-prefill.
        // When the pool runs dry, the index chain is evicted FIRST
        // (frees nothing: both owners live), then request 2 preempts —
        // its private pages reclaim, the shared page survives via its
        // other owner.
        let mut s = prefix_sched(2, 6)
            .with_partial_cow(false)
            .with_reserve(ReservationPolicy::Lazy);
        s.submit(shared_req(1, 20)).unwrap();
        s.plan_admissions();
        s.record_prefill(0, 9).unwrap();
        assert_eq!(s.prefix_entries(), 2);
        s.submit(shared_req(2, 20)).unwrap();
        assert_eq!(s.plan_admissions(), vec![1]);
        let donor = s.page_table(0).unwrap()[0];
        assert_eq!(s.page_table(1).unwrap()[0], donor);
        assert_eq!(s.free_pages(), 1, "3 + 2 private of 6 pages bound");
        // lane 0 decodes rows 8..12, grows into the last free page,
        // then runs dry at row 16 while lane 1 still prefills
        loop {
            let g = s.ensure_decode_backing().unwrap();
            if !g.preempted.is_empty() {
                assert_eq!((g.preempted[0].lane, g.preempted[0].id), (1, 2),
                           "the prefilling sharer is youngest: preempted");
                break;
            }
            s.record_decode(0, 3).unwrap();
        }
        assert_eq!(s.prefix_entries(), 0,
                   "resident chains must evict before any preemption");
        assert_eq!(s.active(), 1);
        assert_eq!(s.queued(), 1, "victim requeued for recompute");
        // the shared page survives its releaser: lane 0 still reads it
        assert!(s.page_table(0).unwrap().contains(&donor));
        assert_eq!(s.page_stats().pages_in_use, lane_held_pages(&s),
                   "victim's private pages must be reclaimed, shared \
                    page must stay charged to its surviving owner");
    }

    #[test]
    fn prefix_share_coerced_off_on_dense_pools() {
        let s = Scheduler::new(2, 4, 12, false).with_prefix_share(true);
        assert!(!s.prefix_share());
        let s = Scheduler::paged(2, 4, 32, 8, 4).with_prefix_share(true);
        assert!(s.prefix_share());
    }

    // -- quantized page headers (PR 8) -------------------------------------

    use super::super::kv::{sim_rows_amax_k as amax_k, sim_rows_amax_v as amax_v};

    /// Expected header for a page holding exactly `rows`.
    fn int8_header(rows: &[i32]) -> PageHeader {
        PageHeader {
            k_scale: PageCodec::Int8Sym.scale_for(amax_k(rows)),
            v_scale: PageCodec::Int8Sym.scale_for(amax_v(rows)),
        }
    }

    #[test]
    fn kv_codec_coerced_to_fp16_on_dense_pools() {
        let s = Scheduler::new(2, 4, 12, false).with_kv_codec(PageCodec::Int8Sym);
        assert_eq!(s.kv_codec(), PageCodec::Fp16);
        assert_eq!(s.kv_bytes_per_row_effective(), 2.0);
        let s = Scheduler::paged(2, 4, 32, 4, 8).with_kv_codec(PageCodec::Int8Sym);
        assert_eq!(s.kv_codec(), PageCodec::Int8Sym);
        // 1 byte/elem + 8 header bytes over 4 rows
        assert_eq!(s.kv_bytes_per_row_effective(), 3.0);
    }

    #[test]
    fn quantized_writes_stamp_scales_over_resident_rows() {
        // prompt 8 over 4-row pages, 3-token chunks: page 0 is stamped
        // twice (partial then full), page 1 twice, and the decode page
        // re-stamps on every generated row
        let mut s = Scheduler::paged(2, 8, 32, 4, 8)
            .with_kv_codec(PageCodec::Int8Sym);
        let prompt: Vec<i32> = (100..108).collect();
        s.submit(GenRequest::new(1, prompt.clone(), 4)).unwrap();
        s.plan_admissions();
        let table: Vec<u32> = s.page_table(0).unwrap().to_vec();
        s.record_chunk(0, 3, 0).unwrap();
        assert_eq!(s.page_header(table[0]), int8_header(&prompt[0..3]),
                   "partial page: scale covers exactly the resident rows");
        s.record_chunk(0, 3, 0).unwrap();
        assert_eq!(s.page_header(table[0]), int8_header(&prompt[0..4]),
                   "page 0 re-stamped when its last row lands");
        assert_eq!(s.page_header(table[1]), int8_header(&prompt[4..6]));
        s.record_chunk(0, 2, 77).unwrap();
        assert_eq!(s.page_header(table[1]), int8_header(&prompt[4..8]));
        // decode row 8 carries the KV of the prefill's first token (77)
        s.record_decode(0, 78).unwrap();
        assert_eq!(s.page_header(table[2]), int8_header(&[77]));
        s.record_decode(0, 79).unwrap();
        assert_eq!(s.page_header(table[2]), int8_header(&[77, 78]),
                   "decode page re-stamps as generated rows accumulate");
    }

    #[test]
    fn fp16_pool_headers_stay_identity() {
        let mut s = Scheduler::paged(2, 8, 32, 4, 8); // default Fp16
        s.submit(GenRequest::new(1, (100..108).collect(), 2)).unwrap();
        s.plan_admissions();
        let table: Vec<u32> = s.page_table(0).unwrap().to_vec();
        s.record_prefill(0, 9).unwrap();
        s.record_decode(0, 3).unwrap();
        for page in table {
            assert_eq!(s.page_header(page), PageHeader::default(),
                       "fp16 pages must never stamp a non-identity scale");
        }
    }

    #[test]
    fn cow_fork_restamps_the_destination_scale() {
        // craft a prompt whose page-1 amax lives in its LAST row: the
        // COW fork copies only rows 4..7, so the destination's fresh
        // scale must be strictly tighter than the donor's full-page one
        let base = [20, 21, 22];
        let spike = (0..4096)
            .find(|&t| amax_k(&[t]) > amax_k(&base) && amax_v(&[t]) > amax_v(&base))
            .expect("sim model has wide magnitude spread");
        let mut prompt: Vec<i32> = (10..14).collect();
        prompt.extend_from_slice(&base);
        prompt.push(spike);
        let mut s = Scheduler::paged(2, 8, 32, 4, 8)
            .with_prefix_share(true)
            .with_kv_codec(PageCodec::Int8Sym);
        s.submit(GenRequest::new(1, prompt.clone(), 2)).unwrap();
        s.plan_admissions();
        s.record_prefill(0, 9).unwrap();
        let donor = s.page_table(0).unwrap()[1];
        assert_eq!(s.page_header(donor), int8_header(&prompt[4..8]));
        // identical prompt: shares page 0, COW-forks rows 4..7 of page 1
        s.submit(GenRequest::new(2, prompt.clone(), 2)).unwrap();
        assert_eq!(s.plan_admissions(), vec![1]);
        assert_eq!(s.shared_bind(1),
                   Some(SharedBind { resident_rows: 7, shared_pages: 1,
                                     cow_rows: 3 }));
        let dest = s.page_table(1).unwrap()[1];
        assert_ne!(dest, donor, "fork must land in a private page");
        assert_eq!(s.page_header(dest), int8_header(&prompt[4..7]),
                   "destination scale must cover the COPIED rows only");
        assert_ne!(s.page_header(dest), s.page_header(donor),
                   "aliasing the donor header would quantize the fork's \
                    subsequent rows on the wrong grid");
        // the fork's own final prompt row re-stamps over rows 4..8
        s.record_chunk(1, 1, 5).unwrap();
        assert_eq!(s.page_header(dest), int8_header(&prompt[4..8]));
    }

    #[test]
    fn imported_lane_restamps_its_pages() {
        let mk = || Scheduler::paged(2, 4, 32, 4, 8)
            .with_kv_codec(PageCodec::Int8Sym);
        let mut src = mk();
        let prompt: Vec<i32> = (200..204).collect();
        src.submit(GenRequest::new(1, prompt.clone(), 8)).unwrap();
        src.plan_admissions();
        src.record_prefill(0, 50).unwrap();
        src.record_decode(0, 51).unwrap();
        let moved = src.take_migratable();
        assert_eq!(moved.len(), 1);
        let mut dst = mk();
        let lane = dst.import_lane(&moved[0].1).unwrap();
        let table: Vec<u32> = dst.page_table(lane).unwrap().to_vec();
        assert_eq!(dst.page_header(table[0]), int8_header(&prompt),
                   "imported prompt page must carry its header");
        // rows written = 4 prompt + 1 decoded (token 50's KV at row 4)
        assert_eq!(dst.page_header(table[1]), int8_header(&[50]),
                   "imported decode page must carry its header");
    }

    #[test]
    fn dense_reserves_exactly_one_page_per_lane() {
        // the PR 2 degenerate configuration: admission-by-pages must
        // coincide with admission-by-free-lane
        let mut s = sched();
        assert!(!s.is_paged());
        for i in 0..4 {
            s.submit(req(i, 2)).unwrap();
        }
        assert_eq!(s.plan_admissions().len(), 2);
        let stats = s.page_stats();
        assert_eq!((stats.total_pages, stats.pages_in_use), (2, 2));
    }
}
