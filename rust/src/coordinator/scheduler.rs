//! Iteration-level continuous-batching scheduler.
//!
//! Replaces the old batch-at-a-time `Batcher` (which padded partial
//! batches by duplicating a real lane and decoded every lane to the
//! batch max). The scheduler owns an admission queue and the fixed
//! [`KvPool`] of decode lanes; each [`Engine::step`](super::Engine::step)
//! runs ONE scheduler tick. Lanes finish independently — per-request
//! `max_new_tokens` and stop tokens — and a freed lane is backfilled
//! from the queue on the very next iteration, so no decode slot is ever
//! spent on a finished or duplicated request.
//!
//! Admission prefill is governed by a [`PrefillPolicy`]:
//!
//! * [`PrefillPolicy::Blocking`] — the PR 1 behavior: one whole-pool
//!   prefill invocation warms every admitted lane before the tick's
//!   decode iteration. Simple, but every queued request's TTFT inflates
//!   while decode stalls behind the prompt.
//! * [`PrefillPolicy::Chunked`] — prompts stream into their lanes in
//!   `chunk_len`-token slices interleaved with decode iterations (the
//!   stage-customized hardware story: the prefill engine chews prompt
//!   chunks while the decode engine keeps stepping resident lanes). A
//!   request occupying a lane mid-prompt is in the
//!   [`RequestPhase::Prefilling`] state and joins decode iterations only
//!   once its prompt is cache-resident.
//!
//! Admission policy is capability-driven: with a per-lane-position
//! backend (`BackendSpec::per_lane_pos`) any free lane is backfilled
//! immediately; with an aligned-only backend the scheduler gang-admits
//! into an all-free pool (still padding-free, still stop-token aware).

use std::collections::VecDeque;
use std::time::Instant;

use anyhow::{anyhow, Result};

use super::backend::LaneStep;
use super::kv::KvPool;
use super::request::{FinishReason, GenRequest, GenResult};

/// How admission prefill shares the engine with decode iterations.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PrefillPolicy {
    /// Whole-prompt, whole-pool admission prefill (PR 1 behavior): the
    /// tick's decode iteration waits for the full prefill invocation.
    Blocking,
    /// Stream prompts in `chunk_len`-token slices interleaved with
    /// decode iterations.
    Chunked {
        /// Prompt tokens per prefill chunk (≥ 1; the final chunk of a
        /// prompt may be shorter).
        chunk_len: usize,
        /// When true (the default posture), at most ONE chunk is issued
        /// per tick so resident lanes keep their decode cadence; when
        /// false every prefilling lane gets a chunk each tick (drains
        /// admissions faster at the decode lanes' expense).
        decode_priority: bool,
    },
}

impl PrefillPolicy {
    /// Chunked with the decode-protecting default.
    pub fn chunked(chunk_len: usize) -> Self {
        PrefillPolicy::Chunked { chunk_len, decode_priority: true }
    }
}

/// Where a lane-resident request is in its lifecycle.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RequestPhase {
    /// The prompt is streaming into the lane's cache; `next_chunk` is
    /// the index of the next chunk to issue (chunk 0 starts at cache
    /// position 0).
    Prefilling { next_chunk: usize },
    /// The prompt is resident; the lane joins decode iterations.
    Decoding,
}

/// A retired request paired with its admission sequence number, so
/// drain-style callers can restore submission order across iterations.
pub type Completion = (u64, GenResult);

/// One planned prefill chunk: feed `tokens` into `lane` starting at
/// cache position `start_pos`. `last` marks the chunk that completes
/// the prompt (its logits yield the request's first generated token).
#[derive(Debug, Clone, Copy)]
pub struct ChunkPlan<'a> {
    pub lane: usize,
    pub start_pos: usize,
    pub tokens: &'a [i32],
    pub last: bool,
}

/// A queued request with its submission order and arrival time.
#[derive(Debug, Clone)]
struct Pending {
    req: GenRequest,
    seq: u64,
    arrived: Instant,
}

/// A request occupying a decode lane.
#[derive(Debug)]
struct InFlight {
    req: GenRequest,
    seq: u64,
    arrived: Instant,
    admitted_at: Instant,
    phase: RequestPhase,
    tokens: Vec<i32>,
    first_token_at: Instant,
}

impl InFlight {
    fn finish_reason(&self) -> Option<FinishReason> {
        match self.tokens.last() {
            Some(last) if self.req.stop_tokens.contains(last) => Some(FinishReason::Stop),
            Some(_) if self.tokens.len() >= self.req.max_new_tokens => {
                Some(FinishReason::Length)
            }
            _ => None,
        }
    }

    fn into_result(self, now: Instant) -> Completion {
        let finish_reason = self.finish_reason().unwrap_or(FinishReason::Length);
        (self.seq, GenResult {
            id: self.req.id,
            tokens: self.tokens,
            ttft: self.first_token_at - self.arrived,
            queue_wait: self.admitted_at - self.arrived,
            decode_time: now - self.first_token_at,
            finish_reason,
        })
    }
}

/// Admission queue + lane pool + in-flight state.
pub struct Scheduler {
    pool: KvPool,
    queue: VecDeque<Pending>,
    lanes: Vec<Option<InFlight>>,
    /// Gang admission (aligned-only backends): admit only when the pool
    /// is completely free.
    pub gang: bool,
    next_seq: u64,
}

impl Scheduler {
    pub fn new(lanes: usize, prefill_len: usize, max_seq: usize, gang: bool) -> Self {
        Scheduler {
            pool: KvPool::new(lanes, prefill_len, max_seq),
            queue: VecDeque::new(),
            lanes: (0..lanes).map(|_| None).collect(),
            gang,
            next_seq: 0,
        }
    }

    pub fn lanes(&self) -> usize {
        self.pool.lanes()
    }

    pub fn prefill_len(&self) -> usize {
        self.pool.prefill_len
    }

    pub fn max_seq(&self) -> usize {
        self.pool.max_seq
    }

    /// Validate a request against the artifact shapes.
    pub fn validate(&self, req: &GenRequest) -> Result<()> {
        if req.prompt.len() != self.pool.prefill_len {
            return Err(anyhow!(
                "request {}: prompt length {} != artifact prefill length {} \
                 (fixed-shape AOT artifacts)",
                req.id, req.prompt.len(), self.pool.prefill_len
            ));
        }
        if req.max_new_tokens == 0 {
            return Err(anyhow!("request {}: max_new_tokens must be > 0", req.id));
        }
        if self.pool.prefill_len + req.max_new_tokens > self.pool.max_seq {
            return Err(anyhow!(
                "request {}: {} prompt + {} new tokens exceeds max_seq {}",
                req.id, self.pool.prefill_len, req.max_new_tokens, self.pool.max_seq
            ));
        }
        Ok(())
    }

    /// Enqueue a validated request; its TTFT clock starts now.
    pub fn submit(&mut self, req: GenRequest) -> Result<()> {
        self.validate(&req)?;
        let seq = self.next_seq;
        self.next_seq += 1;
        self.queue.push_back(Pending { req, seq, arrived: Instant::now() });
        Ok(())
    }

    pub fn queued(&self) -> usize {
        self.queue.len()
    }

    /// Sequence number the next submission will receive.
    pub fn seq_watermark(&self) -> u64 {
        self.next_seq
    }

    pub fn active(&self) -> usize {
        self.pool.active_count()
    }

    pub fn has_work(&self) -> bool {
        !self.queue.is_empty() || !self.pool.is_empty()
    }

    /// Pick the lanes to admit this iteration and bind them (empty cache
    /// rows, [`RequestPhase::Prefilling`] at chunk 0). Returns the bound
    /// lanes; the engine then feeds each prompt through the policy's
    /// prefill path.
    pub fn plan_admissions(&mut self) -> Vec<usize> {
        if self.queue.is_empty() || (self.gang && !self.pool.is_empty()) {
            return Vec::new();
        }
        let free = self.pool.free_lanes();
        let mut admitted = Vec::new();
        let now = Instant::now();
        for lane in free {
            let Some(p) = self.queue.pop_front() else { break };
            self.pool
                .bind(lane, p.req.id, p.req.prompt.len())
                .expect("free lane bind cannot fail");
            self.lanes[lane] = Some(InFlight {
                req: p.req,
                seq: p.seq,
                arrived: p.arrived,
                admitted_at: now,
                phase: RequestPhase::Prefilling { next_chunk: 0 },
                // placeholder; overwritten when the prefill completes
                first_token_at: p.arrived,
                tokens: Vec::new(),
            });
            admitted.push(lane);
        }
        admitted
    }

    /// Request id bound to `lane` (0 when unbound; used for event labels).
    pub fn prompt_owner(&self, lane: usize) -> u64 {
        self.lanes
            .get(lane)
            .and_then(|l| l.as_ref())
            .map(|f| f.req.id)
            .unwrap_or(0)
    }

    /// Tokens the request on `lane` has generated so far.
    pub fn generated(&self, lane: usize) -> usize {
        self.lanes
            .get(lane)
            .and_then(|l| l.as_ref())
            .map(|f| f.tokens.len())
            .unwrap_or(0)
    }

    /// Prompt of the request bound to `lane`.
    pub fn prompt(&self, lane: usize) -> Result<&[i32]> {
        self.lanes
            .get(lane)
            .and_then(|l| l.as_ref())
            .map(|f| f.req.prompt.as_slice())
            .ok_or_else(|| anyhow!("no request bound to lane {lane}"))
    }

    /// Lifecycle phase of the request on `lane` (None when unbound).
    pub fn phase(&self, lane: usize) -> Option<RequestPhase> {
        self.lanes.get(lane).and_then(|l| l.as_ref()).map(|f| f.phase)
    }

    /// Lanes with a prompt still streaming in, oldest admission first —
    /// FIFO chunk service completes the head request's prefill (and thus
    /// its first token) soonest.
    pub fn prefilling_lanes(&self) -> Vec<usize> {
        let mut lanes: Vec<usize> = self
            .pool
            .active_lanes()
            .into_iter()
            .filter(|&l| {
                matches!(self.lanes[l].as_ref().map(|f| f.phase),
                         Some(RequestPhase::Prefilling { .. }))
            })
            .collect();
        lanes.sort_by_key(|&l| self.lanes[l].as_ref().map(|f| f.seq).unwrap_or(u64::MAX));
        lanes
    }

    /// The next chunk to feed `lane` under `chunk_len`. The final chunk
    /// of a prompt may be shorter than `chunk_len` (prompt length not a
    /// multiple) or the whole prompt (prompt shorter than one chunk).
    pub fn next_chunk(&self, lane: usize, chunk_len: usize) -> Result<ChunkPlan<'_>> {
        if chunk_len == 0 {
            return Err(anyhow!("chunk_len must be > 0"));
        }
        let flight = self
            .lanes
            .get(lane)
            .and_then(|l| l.as_ref())
            .ok_or_else(|| anyhow!("no request bound to lane {lane}"))?;
        let RequestPhase::Prefilling { next_chunk } = flight.phase else {
            return Err(anyhow!("lane {lane} is not prefilling"));
        };
        let start_pos = next_chunk * chunk_len;
        let prompt = flight.req.prompt.as_slice();
        if start_pos >= prompt.len() {
            return Err(anyhow!(
                "lane {lane}: chunk {next_chunk} starts past the prompt \
                 ({start_pos} >= {})", prompt.len()));
        }
        let end = (start_pos + chunk_len).min(prompt.len());
        Ok(ChunkPlan {
            lane,
            start_pos,
            tokens: &prompt[start_pos..end],
            last: end == prompt.len(),
        })
    }

    /// Record a completed prefill chunk of `len` tokens on `lane`. For a
    /// non-final chunk `token` is ignored (the artifact's intermediate
    /// logits are meaningless mid-prompt). The final chunk delivers the
    /// request's first generated token exactly like a blocking prefill —
    /// completing immediately when the budget is one token or the first
    /// token is a stop token.
    pub fn record_chunk(&mut self, lane: usize, len: usize, token: i32)
        -> Result<Option<Completion>>
    {
        let now = Instant::now();
        self.pool.fill(lane, len)?;
        let warm = self.pool.is_warm(lane);
        let flight = self
            .lanes
            .get_mut(lane)
            .and_then(|l| l.as_mut())
            .ok_or_else(|| anyhow!("chunk result for unbound lane {lane}"))?;
        match flight.phase {
            RequestPhase::Prefilling { next_chunk } => {
                if warm {
                    flight.phase = RequestPhase::Decoding;
                    flight.first_token_at = now;
                    flight.tokens.push(token);
                    self.retire_if_finished(lane, now)
                } else {
                    flight.phase = RequestPhase::Prefilling { next_chunk: next_chunk + 1 };
                    Ok(None)
                }
            }
            RequestPhase::Decoding => {
                Err(anyhow!("chunk result for lane {lane} already decoding"))
            }
        }
    }

    /// Record a blocking prefill's first token: the whole prompt lands
    /// at once and the lane moves straight to decoding; completes
    /// immediately when the budget is one token or the first token is a
    /// stop token.
    pub fn record_prefill(&mut self, lane: usize, token: i32) -> Result<Option<Completion>> {
        let remaining = self.pool.prefill_remaining(lane);
        self.record_chunk(lane, remaining, token)
    }

    /// The decode iteration plan: every warm lane with its last token
    /// and write position. Lanes still prefilling are excluded — their
    /// prompts are not yet cache-resident.
    pub fn decode_steps(&self) -> Vec<LaneStep> {
        self.pool
            .active_lanes()
            .into_iter()
            .filter(|&lane| self.pool.is_warm(lane))
            .filter_map(|lane| {
                let flight = self.lanes[lane].as_ref()?;
                let slot = self.pool.slot(lane)?;
                Some(LaneStep { lane, token: *flight.tokens.last()?, pos: slot.pos })
            })
            .collect()
    }

    /// Record one decoded token on `lane`, advancing its cache position.
    pub fn record_decode(&mut self, lane: usize, token: i32) -> Result<Option<Completion>> {
        let now = Instant::now();
        self.pool.advance(lane)?;
        let flight = self
            .lanes
            .get_mut(lane)
            .and_then(|l| l.as_mut())
            .ok_or_else(|| anyhow!("decode result for unbound lane {lane}"))?;
        flight.tokens.push(token);
        self.retire_if_finished(lane, now)
    }

    fn retire_if_finished(&mut self, lane: usize, now: Instant) -> Result<Option<Completion>> {
        let flight = self.lanes[lane].as_ref().expect("lane checked by caller");
        let exhausted = self.pool.remaining(lane) == 0;
        if flight.finish_reason().is_none() && !exhausted {
            return Ok(None);
        }
        let flight = self.lanes[lane].take().expect("lane occupied");
        self.pool.release(lane)?;
        Ok(Some(flight.into_result(now)))
    }

    /// Drop everything — queued and in-flight — after a backend error so
    /// the engine thread can keep serving subsequent requests.
    pub fn abort_all(&mut self) {
        self.queue.clear();
        for lane in self.pool.active_lanes() {
            let _ = self.pool.release(lane);
        }
        for slot in &mut self.lanes {
            *slot = None;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sched() -> Scheduler {
        Scheduler::new(2, 4, 12, false)
    }

    fn req(id: u64, new: usize) -> GenRequest {
        GenRequest::new(id, vec![id as i32; 4], new)
    }

    #[test]
    fn validates_prompt_shape() {
        let mut s = sched();
        assert!(s.submit(GenRequest::new(1, vec![0; 3], 2)).is_err());
        assert!(s.submit(GenRequest::new(1, vec![0; 4], 0)).is_err());
        assert!(s.submit(GenRequest::new(1, vec![0; 4], 9)).is_err());
        assert!(s.submit(req(1, 8)).is_ok());
    }

    #[test]
    fn admits_up_to_pool_capacity() {
        let mut s = sched();
        for i in 0..3 {
            s.submit(req(i, 2)).unwrap();
        }
        let admitted = s.plan_admissions();
        assert_eq!(admitted, vec![0, 1]);
        assert_eq!(s.queued(), 1);
        assert_eq!(s.active(), 2);
        assert!(s.plan_admissions().is_empty());
    }

    #[test]
    fn lane_frees_and_backfills() {
        let mut s = sched();
        s.submit(req(1, 1)).unwrap();
        s.submit(req(2, 3)).unwrap();
        s.submit(req(3, 2)).unwrap();
        let admitted = s.plan_admissions();
        assert_eq!(admitted.len(), 2);
        // request 1 has a 1-token budget: finishes at prefill
        let (seq, done) = s.record_prefill(0, 7).unwrap().unwrap();
        assert_eq!(seq, 0);
        assert_eq!(done.id, 1);
        assert_eq!(done.finish_reason, FinishReason::Length);
        assert!(s.record_prefill(1, 8).unwrap().is_none());
        // freed lane 0 is immediately backfillable
        assert_eq!(s.plan_admissions(), vec![0]);
    }

    #[test]
    fn stop_token_retires_lane() {
        let mut s = sched();
        s.submit(req(1, 8).with_stop_tokens(vec![42])).unwrap();
        s.plan_admissions();
        assert!(s.record_prefill(0, 5).unwrap().is_none());
        let (_, done) = s.record_decode(0, 42).unwrap().unwrap();
        assert_eq!(done.finish_reason, FinishReason::Stop);
        assert_eq!(done.tokens, vec![5, 42]);
        assert_eq!(s.active(), 0);
    }

    #[test]
    fn gang_mode_waits_for_empty_pool() {
        let mut s = Scheduler::new(2, 4, 12, true);
        s.submit(req(1, 2)).unwrap();
        s.submit(req(2, 2)).unwrap();
        s.submit(req(3, 2)).unwrap();
        assert_eq!(s.plan_admissions().len(), 2);
        s.record_prefill(0, 1).unwrap();
        s.record_prefill(1, 1).unwrap();
        // one lane finishes; gang mode must NOT backfill yet
        let done = s.record_decode(0, 1).unwrap();
        assert!(done.is_some());
        assert!(s.plan_admissions().is_empty());
        let done = s.record_decode(1, 1).unwrap();
        assert!(done.is_some());
        assert_eq!(s.plan_admissions(), vec![0]);
    }

    #[test]
    fn decode_steps_cover_exactly_active_lanes() {
        let mut s = sched();
        s.submit(req(1, 4)).unwrap();
        s.submit(req(2, 4)).unwrap();
        s.plan_admissions();
        s.record_prefill(0, 1).unwrap();
        s.record_prefill(1, 2).unwrap();
        let steps = s.decode_steps();
        assert_eq!(steps.len(), 2);
        assert_eq!(steps[0].pos, 4);
        assert_eq!(steps[0].token, 1);
        s.record_decode(0, 9).unwrap();
        let steps = s.decode_steps();
        assert_eq!(steps[0].pos, 5);
        assert_eq!(steps[0].token, 9);
    }

    #[test]
    fn kv_exhaustion_forces_length_finish() {
        // max_seq 6, prefill 4 → at most 2 generated tokens fit
        let mut s = Scheduler::new(1, 4, 6, false);
        s.submit(GenRequest::new(1, vec![0; 4], 2)).unwrap();
        s.plan_admissions();
        assert!(s.record_prefill(0, 1).unwrap().is_none());
        let (_, done) = s.record_decode(0, 2).unwrap().unwrap();
        assert_eq!(done.tokens.len(), 2);
        assert_eq!(done.finish_reason, FinishReason::Length);
    }

    #[test]
    fn chunked_prefill_state_machine() {
        let mut s = sched();
        s.submit(req(1, 4)).unwrap();
        s.submit(req(2, 4)).unwrap();
        s.plan_admissions();
        assert_eq!(s.prefilling_lanes(), vec![0, 1]);
        assert_eq!(s.phase(0), Some(RequestPhase::Prefilling { next_chunk: 0 }));
        // prefilling lanes do not decode
        assert!(s.decode_steps().is_empty());

        // 3-token chunks over a 4-token prompt: chunks of 3 and 1
        let plan = s.next_chunk(0, 3).unwrap();
        assert_eq!((plan.start_pos, plan.tokens.len(), plan.last), (0, 3, false));
        assert!(s.record_chunk(0, 3, 0).unwrap().is_none());
        assert_eq!(s.phase(0), Some(RequestPhase::Prefilling { next_chunk: 1 }));
        let plan = s.next_chunk(0, 3).unwrap();
        assert_eq!((plan.start_pos, plan.tokens.len(), plan.last), (3, 1, true));
        assert!(s.record_chunk(0, 1, 9).unwrap().is_none());
        assert_eq!(s.phase(0), Some(RequestPhase::Decoding));
        // lane 0 decodes while lane 1 is still prefilling
        assert_eq!(s.prefilling_lanes(), vec![1]);
        let steps = s.decode_steps();
        assert_eq!(steps.len(), 1);
        assert_eq!((steps[0].lane, steps[0].token, steps[0].pos), (0, 9, 4));

        // prompt shorter than one chunk: a single final chunk
        let plan = s.next_chunk(1, 64).unwrap();
        assert_eq!((plan.start_pos, plan.tokens.len(), plan.last), (0, 4, true));
        assert!(s.record_chunk(1, 4, 7).unwrap().is_none());
        assert_eq!(s.decode_steps().len(), 2);
        // chunk ops on a decoding lane are an error
        assert!(s.next_chunk(1, 4).is_err());
        assert!(s.record_chunk(1, 1, 0).is_err());
    }

    #[test]
    fn chunked_first_token_can_retire_immediately() {
        let mut s = sched();
        s.submit(req(1, 1)).unwrap(); // 1-token budget
        s.submit(req(2, 8).with_stop_tokens(vec![42])).unwrap();
        s.plan_admissions();
        // budget-1 request retires on its final chunk
        assert!(s.record_chunk(0, 2, 5).unwrap().is_none());
        let (_, done) = s.record_chunk(0, 2, 5).unwrap().unwrap();
        assert_eq!(done.finish_reason, FinishReason::Length);
        assert_eq!(done.tokens, vec![5]);
        // stop token as the first generated token retires too
        assert!(s.record_chunk(1, 2, 0).unwrap().is_none());
        let (_, done) = s.record_chunk(1, 2, 42).unwrap().unwrap();
        assert_eq!(done.finish_reason, FinishReason::Stop);
        assert_eq!(s.active(), 0);
    }

    #[test]
    fn freed_lane_backfills_while_neighbor_half_prefilled() {
        let mut s = sched();
        s.submit(req(1, 1)).unwrap();
        s.submit(req(2, 4)).unwrap();
        s.submit(req(3, 2)).unwrap();
        s.plan_admissions();
        // lane 1 gets half its prompt; lane 0 completes and retires
        assert!(s.record_chunk(1, 2, 0).unwrap().is_none());
        assert!(s.record_prefill(0, 7).unwrap().is_some());
        // the freed lane backfills while lane 1 is still mid-prompt
        assert_eq!(s.plan_admissions(), vec![0]);
        assert_eq!(s.prefilling_lanes(), vec![1, 0]); // oldest (seq) first
        assert_eq!(s.phase(0), Some(RequestPhase::Prefilling { next_chunk: 0 }));
        assert_eq!(s.phase(1), Some(RequestPhase::Prefilling { next_chunk: 1 }));
    }

    #[test]
    fn abort_clears_everything() {
        let mut s = sched();
        s.submit(req(1, 4)).unwrap();
        s.submit(req(2, 4)).unwrap();
        s.submit(req(3, 4)).unwrap();
        s.plan_admissions();
        s.abort_all();
        assert!(!s.has_work());
        assert_eq!(s.queued(), 0);
        assert_eq!(s.active(), 0);
    }
}
