//! SLO-aware front door (ISSUE 10): the admission-policy layer that
//! sits between `Router::submit` / the open-loop drivers and the
//! per-shard schedulers.
//!
//! Everything here is PURE POLICY — small deterministic state machines
//! with no channels, threads or clocks — so the threaded Router
//! coordinator, the virtual-time open-loop harness and the inline CLI
//! driver all share the exact same decisions and cannot drift apart:
//!
//! * [`SloClass`] / [`Slo`] — per-request service class with TTFT/TPOT
//!   deadlines, carried on `GenRequest` and validated with the rest of
//!   the request shape. `Interactive` is never shed; `Batch` is the
//!   deferrable/sheddable bulk tier.
//! * [`FrontDoorConfig`] — the three knobs (enabled, shed watermark,
//!   stealing), validated through `ServeConfig::validate`.
//! * [`FrontDoorConfig::shed`] — the load-shed decision: when the
//!   pool-wide queued page demand exceeds the watermark (a fraction of
//!   total pool pages — the point where projected queue wait blows an
//!   Interactive TTFT deadline under the modeled drain rate), new
//!   Batch submissions are rejected with a typed [`Overloaded`] error
//!   instead of parking in the overflow queue forever.
//! * [`overflow_insert`] — the deferral arm: with the front door on,
//!   the shared overflow queue becomes a two-level priority queue
//!   (Interactive FIFO ahead of Batch FIFO). With the door off, or a
//!   uniform class, it is exactly `push_back` — PR 9 ordering
//!   bit-for-bit, which is what keeps zero-overload streams
//!   byte-identical.
//! * [`AdaptiveChunk`] — the chunk-width controller behind
//!   `PrefillPolicy::Adaptive`: queue depth grows the chunk toward
//!   `max_chunk` (drain the prompt backlog), an empty queue shrinks it
//!   toward `min_chunk` (protect decode cadence). Deterministic, no
//!   clock, no RNG — chunk width changes modeled timing, never token
//!   bytes.
//! * [`pick_donor`] / [`RequestTooWide`] — the work-stealing donor
//!   rule and the typed fail-fast for requests wider than any single
//!   shard's pool (the overflow head-of-line livelock fix).
//!
//! The crate's `anyhow` replacement carries messages, not payloads, so
//! "typed" errors here are real `std::error::Error` structs whose
//! `Display` opens with a stable prefix; the `matches` helpers classify
//! an `Error` that has already crossed the channel boundary.

use std::collections::VecDeque;
use std::fmt;

use crate::anyhow::{Error, Result};
use crate::bail;

// ---------------------------------------------------------------------------
// SLO classes and per-request deadlines
// ---------------------------------------------------------------------------

/// Service class of a request. `Batch` is the default: unmarked
/// traffic is deferrable, and only explicitly `Interactive` requests
/// get priority (and shed immunity) at the front door.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum SloClass {
    /// Latency-sensitive: never shed, jumps Batch in the overflow
    /// queue, and its TTFT deadline is what the goodput gate measures.
    Interactive,
    /// Throughput tier: deferred behind Interactive under load and
    /// rejected with [`Overloaded`] past the shed watermark.
    #[default]
    Batch,
}

impl SloClass {
    /// Stable lowercase name (CLI / JSON).
    pub fn name(self) -> &'static str {
        match self {
            SloClass::Interactive => "interactive",
            SloClass::Batch => "batch",
        }
    }

    /// Parse a CLI spelling.
    pub fn parse(s: &str) -> Result<SloClass> {
        match s {
            "interactive" => Ok(SloClass::Interactive),
            "batch" => Ok(SloClass::Batch),
            other => bail!("unknown SLO class '{other}' (interactive|batch)"),
        }
    }
}

/// Per-request SLO: class plus the deadlines goodput is measured
/// against. Deadlines are in (wall or modeled) seconds and must be
/// finite and positive — `validate` runs with the rest of the request
/// shape checks at submit time.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Slo {
    pub class: SloClass,
    /// First-token deadline: a completion "meets SLO" iff its TTFT is
    /// at or under this.
    pub ttft_deadline_s: f64,
    /// Per-output-token deadline (decode cadence budget).
    pub tpot_deadline_s: f64,
}

/// Default Batch deadlines: finite (the hand-rolled JSON emitters map
/// non-finite to 0.0, so `f64::INFINITY` would read as "impossible")
/// but far beyond any modeled makespan — an unmarked request only
/// misses its SLO if it never completes.
const BATCH_TTFT_S: f64 = 1.0e6;
const BATCH_TPOT_S: f64 = 1.0e6;

impl Slo {
    /// Interactive defaults: 1 s to first token, 250 ms per token.
    pub fn interactive() -> Slo {
        Slo { class: SloClass::Interactive, ttft_deadline_s: 1.0, tpot_deadline_s: 0.25 }
    }

    /// Batch defaults: effectively unbounded (but finite) deadlines.
    pub fn batch() -> Slo {
        Slo {
            class: SloClass::Batch,
            ttft_deadline_s: BATCH_TTFT_S,
            tpot_deadline_s: BATCH_TPOT_S,
        }
    }

    /// Override the first-token deadline.
    pub fn with_ttft_deadline(mut self, s: f64) -> Slo {
        self.ttft_deadline_s = s;
        self
    }

    /// Override the per-token deadline.
    pub fn with_tpot_deadline(mut self, s: f64) -> Slo {
        self.tpot_deadline_s = s;
        self
    }

    /// Deadlines must be finite and positive (non-finite values would
    /// make every comparison vacuous and poison the JSON emitters).
    pub fn validate(&self) -> Result<()> {
        for (name, v) in [("ttft", self.ttft_deadline_s), ("tpot", self.tpot_deadline_s)] {
            if !v.is_finite() || v <= 0.0 {
                bail!("SLO {name} deadline must be finite and positive, got {v}");
            }
        }
        Ok(())
    }

    /// Did a completion with this TTFT meet the SLO?
    pub fn met(&self, ttft_s: f64) -> bool {
        ttft_s <= self.ttft_deadline_s
    }
}

impl Default for Slo {
    fn default() -> Slo {
        Slo::batch()
    }
}

// ---------------------------------------------------------------------------
// Front-door configuration
// ---------------------------------------------------------------------------

/// The front-door knobs, validated through `ServeConfig::validate`.
/// Disabled by default: every pre-ISSUE-10 call site keeps PR 9
/// behavior bit-for-bit.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FrontDoorConfig {
    /// Master switch: off = FIFO overflow, no shedding, no stealing.
    pub enabled: bool,
    /// Shed watermark as a fraction of total pool pages: when the
    /// queued page demand exceeds `shed_watermark × total_pages`, new
    /// Batch submissions are rejected with [`Overloaded`]. Values
    /// above 1.0 allow queueing deeper than one full pool turn.
    pub shed_watermark: f64,
    /// Cross-shard work stealing: an idle shard takes the youngest
    /// queued (never prefilled) request from the longest-queued shard.
    pub steal: bool,
}

impl Default for FrontDoorConfig {
    fn default() -> FrontDoorConfig {
        FrontDoorConfig { enabled: false, shed_watermark: 0.75, steal: false }
    }
}

impl FrontDoorConfig {
    /// An enabled front door with default watermark and no stealing.
    pub fn on() -> FrontDoorConfig {
        FrontDoorConfig { enabled: true, ..FrontDoorConfig::default() }
    }

    /// Builder: set the shed watermark.
    pub fn with_shed_watermark(mut self, w: f64) -> FrontDoorConfig {
        self.shed_watermark = w;
        self
    }

    /// Builder: toggle cross-shard stealing.
    pub fn with_steal(mut self, steal: bool) -> FrontDoorConfig {
        self.steal = steal;
        self
    }

    /// Knob sanity, called from `ServeConfig::validate`.
    pub fn validate(&self) -> Result<()> {
        if !self.enabled {
            return Ok(());
        }
        if !self.shed_watermark.is_finite() || self.shed_watermark <= 0.0 {
            bail!(
                "front door: shed watermark must be finite and positive \
                 (a fraction of total pool pages), got {}",
                self.shed_watermark
            );
        }
        Ok(())
    }

    /// The watermark in pages for a pool of `total_pages`.
    pub fn watermark_pages(&self, total_pages: usize) -> usize {
        (((self.shed_watermark * total_pages as f64).ceil()) as usize).max(1)
    }

    /// The load-shed decision at submit time: `Some(Overloaded)` means
    /// the submission must be rejected. Interactive traffic is never
    /// shed; Batch is shed once the queued demand passes the
    /// watermark.
    pub fn shed(&self, slo: &Slo, snap: PoolSnapshot) -> Option<Overloaded> {
        if !self.enabled || slo.class == SloClass::Interactive {
            return None;
        }
        let watermark_pages = self.watermark_pages(snap.total_pages);
        if snap.queued_pages > watermark_pages {
            Some(Overloaded {
                queued_pages: snap.queued_pages,
                watermark_pages,
                total_pages: snap.total_pages,
            })
        } else {
            None
        }
    }
}

/// Pool-wide congestion snapshot the shed decision reads: total pages
/// across live admitting shards and the page demand currently parked
/// (overflow queue plus per-shard admission queues).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct PoolSnapshot {
    pub total_pages: usize,
    pub queued_pages: usize,
}

// ---------------------------------------------------------------------------
// Typed errors
// ---------------------------------------------------------------------------

/// Typed rejection of a Batch submission past the shed watermark. The
/// caller should back off and retry once the backlog drains — the
/// request was NOT queued.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Overloaded {
    pub queued_pages: usize,
    pub watermark_pages: usize,
    pub total_pages: usize,
}

/// Stable `Display` prefix [`Overloaded::matches`] classifies by (the
/// in-tree anyhow carries messages, not payloads).
pub const OVERLOADED_PREFIX: &str = "overloaded:";

impl fmt::Display for Overloaded {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{OVERLOADED_PREFIX} {} queued pages exceed the shed watermark of \
             {} pages ({} pool pages) — batch admission sheds until the \
             backlog drains",
            self.queued_pages, self.watermark_pages, self.total_pages
        )
    }
}

impl std::error::Error for Overloaded {}

impl Overloaded {
    /// Does an error that crossed the channel boundary denote an
    /// overload shed? Checks the whole context chain.
    pub fn matches(e: &Error) -> bool {
        format!("{e:#}").contains(OVERLOADED_PREFIX)
    }
}

/// Typed fail-fast for a request whose page reservation exceeds every
/// single shard's pool: legal against total memory, impossible after
/// `kv::split_budget` — without this check it would park at the shared
/// overflow head forever and starve all later arrivals.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RequestTooWide {
    pub id: u64,
    pub needed_pages: usize,
    pub shard_pages: usize,
}

/// Stable `Display` marker [`RequestTooWide::matches`] classifies by.
pub const TOO_WIDE_MARKER: &str = "too wide for any shard";

impl fmt::Display for RequestTooWide {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "request {} {TOO_WIDE_MARKER}: reservation of {} pages exceeds \
             the per-shard pool of {} pages — lower --kv-overcommit, add \
             pages, or reduce --shards",
            self.id, self.needed_pages, self.shard_pages
        )
    }
}

impl std::error::Error for RequestTooWide {}

impl RequestTooWide {
    /// Does an error denote the per-shard capacity rejection?
    pub fn matches(e: &Error) -> bool {
        format!("{e:#}").contains(TOO_WIDE_MARKER)
    }
}

// ---------------------------------------------------------------------------
// Overflow priority insert (the Batch-deferral arm)
// ---------------------------------------------------------------------------

/// Insert into the shared overflow queue. With the front door enabled,
/// Interactive entries go ahead of every queued Batch entry (stable:
/// after the last queued Interactive), which is the mechanism that
/// keeps Interactive TTFT under deadline while Batch floods. With the
/// door off — or a uniform class — this is exactly `push_back`, so
/// PR 9 dispatch order (and therefore every stream byte) is preserved.
pub fn overflow_insert<T>(
    enabled: bool,
    queue: &mut VecDeque<T>,
    item: T,
    class_of: impl Fn(&T) -> SloClass,
) {
    if enabled && class_of(&item) == SloClass::Interactive {
        let pos = queue
            .iter()
            .position(|t| class_of(t) == SloClass::Batch)
            .unwrap_or(queue.len());
        queue.insert(pos, item);
    } else {
        queue.push_back(item);
    }
}

// ---------------------------------------------------------------------------
// Adaptive chunk-width controller
// ---------------------------------------------------------------------------

/// Deterministic chunk-width controller for `PrefillPolicy::Adaptive`:
/// one observation per engine tick. A non-empty admission queue
/// doubles the width toward `max_chunk` (drain the prompt backlog
/// before it snowballs); an empty queue halves it toward `min_chunk`
/// (small chunks keep decode iterations frequent). No clock, no RNG —
/// the width only moves modeled/wall TIME, never token bytes, because
/// the mock/modeled streams are position-deterministic.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct AdaptiveChunk {
    pub min_chunk: usize,
    pub max_chunk: usize,
    cur: usize,
}

impl AdaptiveChunk {
    /// Controller starting at `min_chunk` (decode-protective until a
    /// backlog proves otherwise). Degenerate bounds are clamped sane.
    pub fn new(min_chunk: usize, max_chunk: usize) -> AdaptiveChunk {
        let min_chunk = min_chunk.max(1);
        let max_chunk = max_chunk.max(min_chunk);
        AdaptiveChunk { min_chunk, max_chunk, cur: min_chunk }
    }

    /// The width the next prefill chunk will use.
    pub fn current(&self) -> usize {
        self.cur
    }

    /// Feed one queue-depth observation; returns the updated width.
    pub fn observe(&mut self, queued: usize) -> usize {
        self.cur = if queued > 0 {
            (self.cur.saturating_mul(2)).min(self.max_chunk)
        } else {
            (self.cur / 2).max(self.min_chunk)
        };
        self.cur
    }
}

// ---------------------------------------------------------------------------
// Work-stealing donor rule
// ---------------------------------------------------------------------------

/// Pick the steal donor: the shard with the deepest stealable queue
/// (queued entries that have NEVER been admitted — preempted resumes
/// already streamed tokens and stay home). Strict maximum, lowest
/// index wins ties; `None` when nothing anywhere is stealable.
pub fn pick_donor(stealable: &[usize]) -> Option<usize> {
    let mut best: Option<(usize, usize)> = None;
    for (i, &n) in stealable.iter().enumerate() {
        if n > 0 && best.map(|(_, bn)| n > bn).unwrap_or(true) {
            best = Some((i, n));
        }
    }
    best.map(|(i, _)| i)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::anyhow::anyhow;

    #[test]
    fn slo_defaults_and_validation() {
        assert_eq!(Slo::default().class, SloClass::Batch);
        assert!(Slo::interactive().validate().is_ok());
        assert!(Slo::batch().validate().is_ok());
        assert!(Slo::interactive().with_ttft_deadline(0.0).validate().is_err());
        assert!(Slo::interactive().with_ttft_deadline(f64::NAN).validate().is_err());
        assert!(Slo::batch().with_tpot_deadline(-1.0).validate().is_err());
        assert!(Slo::interactive().met(1.0));
        assert!(!Slo::interactive().met(1.0001));
        assert_eq!(SloClass::parse("interactive").unwrap(), SloClass::Interactive);
        assert_eq!(SloClass::parse("batch").unwrap(), SloClass::Batch);
        assert!(SloClass::parse("gold").is_err());
    }

    #[test]
    fn shed_fires_only_for_batch_past_watermark() {
        let fd = FrontDoorConfig::on().with_shed_watermark(0.5);
        let calm = PoolSnapshot { total_pages: 40, queued_pages: 20 };
        let hot = PoolSnapshot { total_pages: 40, queued_pages: 21 };
        // at the watermark: admitted; past it: batch shed, interactive kept
        assert!(fd.shed(&Slo::batch(), calm).is_none());
        let shed = fd.shed(&Slo::batch(), hot).expect("past watermark");
        assert_eq!(shed.watermark_pages, 20);
        assert!(fd.shed(&Slo::interactive(), hot).is_none());
        // disabled door never sheds
        let off = FrontDoorConfig::default();
        assert!(off.shed(&Slo::batch(), hot).is_none());
        // validation rejects a nonsense watermark only when enabled
        assert!(FrontDoorConfig::on().with_shed_watermark(0.0).validate().is_err());
        assert!(FrontDoorConfig { enabled: false, shed_watermark: 0.0, steal: false }
            .validate()
            .is_ok());
    }

    #[test]
    fn typed_errors_round_trip_the_message_boundary() {
        let o = Overloaded { queued_pages: 9, watermark_pages: 4, total_pages: 8 };
        let e: Error = anyhow!("{o}").context("submit failed");
        assert!(Overloaded::matches(&e));
        assert!(!RequestTooWide::matches(&e));
        let w = RequestTooWide { id: 7, needed_pages: 12, shard_pages: 10 };
        let e: Error = anyhow!("{w}");
        assert!(RequestTooWide::matches(&e));
        assert!(!Overloaded::matches(&e));
        assert!(format!("{w}").contains("12 pages"));
        assert!(format!("{w}").contains("10 pages"));
    }

    #[test]
    fn overflow_insert_is_fifo_per_class_interactive_first() {
        let class = |t: &(u64, SloClass)| t.1;
        let mut q: VecDeque<(u64, SloClass)> = VecDeque::new();
        overflow_insert(true, &mut q, (0, SloClass::Batch), class);
        overflow_insert(true, &mut q, (1, SloClass::Interactive), class);
        overflow_insert(true, &mut q, (2, SloClass::Batch), class);
        overflow_insert(true, &mut q, (3, SloClass::Interactive), class);
        let order: Vec<u64> = q.iter().map(|t| t.0).collect();
        assert_eq!(order, vec![1, 3, 0, 2], "interactive FIFO ahead of batch FIFO");
        // door off: plain FIFO regardless of class
        let mut q: VecDeque<(u64, SloClass)> = VecDeque::new();
        overflow_insert(false, &mut q, (0, SloClass::Batch), class);
        overflow_insert(false, &mut q, (1, SloClass::Interactive), class);
        let order: Vec<u64> = q.iter().map(|t| t.0).collect();
        assert_eq!(order, vec![0, 1]);
    }

    #[test]
    fn adaptive_chunk_tracks_queue_depth() {
        let mut c = AdaptiveChunk::new(8, 64);
        assert_eq!(c.current(), 8);
        assert_eq!(c.observe(3), 16);
        assert_eq!(c.observe(3), 32);
        assert_eq!(c.observe(1), 64);
        assert_eq!(c.observe(9), 64, "saturates at max_chunk");
        assert_eq!(c.observe(0), 32);
        assert_eq!(c.observe(0), 16);
        assert_eq!(c.observe(0), 8);
        assert_eq!(c.observe(0), 8, "floors at min_chunk");
        // degenerate bounds clamp instead of panicking
        let c = AdaptiveChunk::new(0, 0);
        assert_eq!((c.min_chunk, c.max_chunk, c.current()), (1, 1, 1));
        let c = AdaptiveChunk::new(32, 4);
        assert_eq!((c.min_chunk, c.max_chunk), (32, 32));
    }

    #[test]
    fn donor_is_deepest_stealable_queue() {
        assert_eq!(pick_donor(&[]), None);
        assert_eq!(pick_donor(&[0, 0]), None);
        assert_eq!(pick_donor(&[0, 3, 1]), Some(1));
        assert_eq!(pick_donor(&[2, 2]), Some(0), "lowest index wins ties");
    }
}
