//! Request / response types for the serving coordinator.

use std::time::Duration;

/// A generation request entering the router.
#[derive(Debug, Clone)]
pub struct GenRequest {
    pub id: u64,
    /// Prompt token ids; must be exactly the AOT prefill length (the
    /// batcher validates — fixed-shape artifacts, DESIGN.md §7).
    pub prompt: Vec<i32>,
    /// Number of tokens to generate (greedy).
    pub max_new_tokens: usize,
}

/// Per-request generation result with serving metrics.
#[derive(Debug, Clone)]
pub struct GenResult {
    pub id: u64,
    /// Generated tokens (first = token produced from the prompt).
    pub tokens: Vec<i32>,
    /// Time to first token (prefill + first sample).
    pub ttft: Duration,
    /// Total decode wall time (excludes prefill).
    pub decode_time: Duration,
    /// Whether this lane was batch padding (result should be discarded).
    pub padding: bool,
}

impl GenResult {
    /// Decode throughput for this request, tokens/second.
    pub fn decode_tps(&self) -> f64 {
        if self.tokens.len() <= 1 || self.decode_time.is_zero() {
            return 0.0;
        }
        (self.tokens.len() - 1) as f64 / self.decode_time.as_secs_f64()
    }
}

/// Aggregate serving metrics over a run.
#[derive(Debug, Clone, Default)]
pub struct ServeMetrics {
    pub requests: usize,
    pub batches: usize,
    pub total_prefill: Duration,
    pub total_decode: Duration,
    pub tokens_generated: usize,
    pub prefill_tokens: usize,
}

impl ServeMetrics {
    /// Aggregate decode throughput, tokens/second.
    pub fn decode_tps(&self) -> f64 {
        if self.total_decode.is_zero() {
            return 0.0;
        }
        self.tokens_generated as f64 / self.total_decode.as_secs_f64()
    }

    /// Prefill throughput, tokens/second.
    pub fn prefill_tps(&self) -> f64 {
        if self.total_prefill.is_zero() {
            return 0.0;
        }
        self.prefill_tokens as f64 / self.total_prefill.as_secs_f64()
    }

    /// Mean end-to-end latency per batch.
    pub fn mean_batch_latency(&self) -> Duration {
        if self.batches == 0 {
            return Duration::ZERO;
        }
        (self.total_prefill + self.total_decode) / self.batches as u32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn decode_tps_counts_continuation_tokens() {
        let r = GenResult { id: 0, tokens: vec![1, 2, 3, 4, 5], ttft: Duration::ZERO,
                            decode_time: Duration::from_secs(2), padding: false };
        assert!((r.decode_tps() - 2.0).abs() < 1e-9);
    }

    #[test]
    fn metrics_zero_safe() {
        let m = ServeMetrics::default();
        assert_eq!(m.decode_tps(), 0.0);
        assert_eq!(m.mean_batch_latency(), Duration::ZERO);
    }
}
