//! Request / response types for the serving coordinator.

use std::time::Duration;

use super::frontdoor::Slo;

/// A generation request entering the router.
#[derive(Debug, Clone)]
pub struct GenRequest {
    pub id: u64,
    /// Prompt token ids; must be exactly the AOT prefill length (the
    /// scheduler validates — fixed-shape artifacts, DESIGN.md §7).
    pub prompt: Vec<i32>,
    /// Generation budget (greedy); the scheduler frees the lane early if
    /// a stop token fires first.
    pub max_new_tokens: usize,
    /// Stop tokens (EOS et al.): the lane is released the moment one is
    /// generated. The stop token itself is kept as the final entry of
    /// `GenResult::tokens`. Empty = run to `max_new_tokens`.
    pub stop_tokens: Vec<i32>,
    /// Service class + deadlines the front door (DESIGN.md §16) shapes
    /// admission by. Defaults to Batch with effectively-unbounded
    /// deadlines, which is exactly the pre-front-door behavior.
    pub slo: Slo,
}

impl GenRequest {
    /// Request with no stop tokens (runs to `max_new_tokens`).
    pub fn new(id: u64, prompt: Vec<i32>, max_new_tokens: usize) -> Self {
        GenRequest {
            id,
            prompt,
            max_new_tokens,
            stop_tokens: Vec::new(),
            slo: Slo::default(),
        }
    }

    pub fn with_stop_tokens(mut self, stop_tokens: Vec<i32>) -> Self {
        self.stop_tokens = stop_tokens;
        self
    }

    /// Stamp an SLO class/deadline set on the request.
    pub fn with_slo(mut self, slo: Slo) -> Self {
        self.slo = slo;
        self
    }
}

/// Why a request left its decode lane.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FinishReason {
    /// A stop token was generated.
    Stop,
    /// The `max_new_tokens` budget was exhausted.
    Length,
}

/// Per-request generation result with serving metrics.
#[derive(Debug, Clone)]
pub struct GenResult {
    pub id: u64,
    /// Generated tokens (first = token produced from the prompt).
    pub tokens: Vec<i32>,
    /// Time to first token: queue wait + prefill + first sample.
    pub ttft: Duration,
    /// Time from submission to lane admission (queueing for a free
    /// lane). The rest of the TTFT — [`GenResult::prefill_wait`] — is
    /// the prompt becoming cache-resident.
    pub queue_wait: Duration,
    /// Wall time from the first token to the last (this request's decode
    /// residency, not a batch aggregate).
    pub decode_time: Duration,
    pub finish_reason: FinishReason,
}

impl GenResult {
    /// Admission-to-first-token span: whole-prompt prefill latency under
    /// `Blocking`, chunk streaming (interleaved with other lanes'
    /// decode iterations) under `Chunked`.
    pub fn prefill_wait(&self) -> Duration {
        self.ttft.saturating_sub(self.queue_wait)
    }

    /// Decode throughput for this request, tokens/second.
    pub fn decode_tps(&self) -> f64 {
        if self.tokens.len() <= 1 || self.decode_time.is_zero() {
            return 0.0;
        }
        (self.tokens.len() - 1) as f64 / self.decode_time.as_secs_f64()
    }

    /// Time per output token after the first (TPOT), seconds.
    pub fn tpot_s(&self) -> f64 {
        if self.tokens.len() <= 1 {
            return 0.0;
        }
        self.decode_time.as_secs_f64() / (self.tokens.len() - 1) as f64
    }
}

/// Nearest-rank percentile of an unsorted sample set; 0.0 when empty.
/// Shared by [`ServeMetrics`] and the coordinator's open-loop harness so
/// the CI-gated percentiles can never diverge from the metrics surface.
pub(crate) fn percentile(samples: &[f64], q: f64) -> f64 {
    if samples.is_empty() {
        return 0.0;
    }
    let mut sorted = samples.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
    let rank = ((q / 100.0) * sorted.len() as f64).ceil() as usize;
    sorted[rank.clamp(1, sorted.len()) - 1]
}

/// Aggregate serving metrics over a run.
///
/// The iteration-level scheduler retires requests at different times, so
/// batch-granular aggregates are meaningless; per-request TTFT/TPOT
/// samples carry the latency story and the totals carry throughput.
#[derive(Debug, Clone, Default)]
pub struct ServeMetrics {
    /// Completed requests.
    pub requests: usize,
    /// Whole-pool (blocking) prefill invocations (one may admit several
    /// lanes).
    pub prefill_calls: usize,
    /// Chunked prefill invocations (one chunk of one lane's prompt).
    pub prefill_chunks: usize,
    /// Scheduler TICKS that ran a decode phase (`Engine::step` with at
    /// least one warm lane). Comparable dense-vs-paged: a paged tick
    /// that splits into several artifact calls still counts once here.
    pub iterations: usize,
    /// Decode ARTIFACT invocations. Dense: equals `iterations`. Paged:
    /// one per ≤batch-lane group, so a tick over more warm lanes than
    /// the invocation batch counts several times.
    pub decode_invocations: usize,
    /// Decode lane-steps: sum over invocations of lanes stepped. The
    /// utilization denominator is `decode_invocations × batch width`.
    pub lane_steps: usize,
    pub total_prefill: Duration,
    pub total_decode: Duration,
    pub tokens_generated: usize,
    pub prefill_tokens: usize,
    /// Per-request time-to-first-token samples, seconds.
    pub ttft_s: Vec<f64>,
    /// Per-request queue-wait samples (submission → lane admission),
    /// seconds. With `prefill_wait_s` this splits the TTFT story: is the
    /// tail queueing for lanes or waiting on prompt prefill?
    pub queue_wait_s: Vec<f64>,
    /// Per-request prefill-wait samples (admission → first token),
    /// seconds.
    pub prefill_wait_s: Vec<f64>,
    /// Per-request time-per-output-token samples, seconds.
    pub tpot_s: Vec<f64>,
    /// Peak concurrently admitted requests (the paging headline: at
    /// equal memory a paged pool admits ≥1.5× more on skewed lengths).
    pub peak_active: usize,
    /// Paged-pool geometry: total allocatable pages (0 = dense layout).
    pub kv_pages_total: usize,
    /// Peak pages simultaneously held by live lanes.
    pub kv_pages_peak: usize,
    /// Pages appended to live lanes on demand (lazy reservation).
    pub kv_pages_grown: usize,
    /// Mid-flight page allocations that found the pool dry; each one
    /// triggers a preemption.
    pub grow_failures: usize,
    /// Requests evicted mid-flight (pages released, requeued at the
    /// queue head for recompute). Zero under up-front reservation.
    pub preemptions: usize,
    /// Peak point-in-time rows RESERVED by live lanes vs rows actually
    /// WRITTEN — the reserved-vs-written gap is what lazy reservation
    /// exists to close (their ratio is the live fragmentation).
    pub kv_rows_reserved_peak: usize,
    pub kv_rows_written_peak: usize,
    /// Admissions that bound a RESIDENT shared prefix (zero prefill
    /// chunks for the shared span).
    pub prefix_hits: usize,
    /// Admissions that found no resident prefix (only counted while
    /// prefix sharing is enabled, so hits + misses = admissions and the
    /// hit rate is meaningful).
    pub prefix_misses: usize,
    /// Shared pages bound across all prefix hits (one page backing N
    /// lanes counts once per binding lane — the prefill work avoided).
    pub kv_pages_shared: usize,
    /// Copy-on-write forks performed at admission (partial-page prefix
    /// overlaps copied into a private page).
    pub cow_copies: usize,
    /// Warm lanes handed OFF this shard after their first token
    /// (prefill→decode disaggregation); the request completes on the
    /// importing shard, so `requests` does not count it here.
    pub migrations_out: usize,
    /// Migrated lanes rebuilt ON this shard mid-decode.
    pub migrations_in: usize,
    /// Storage codec label of this engine's KV pool ("fp16", "int8";
    /// empty until the engine stamps it at construction). Merging
    /// shards with DIFFERING codecs yields "mixed" — a pool-level
    /// metric must not claim a codec half its shards don't run.
    pub kv_codec: String,
    /// Effective storage bytes per cache row: element bytes plus the
    /// per-page header amortized over `page_len` (PR 8). This is the
    /// honest denominator of the 2×-capacity claim — INT8 pages cost
    /// 1 byte/elem PLUS the header, not a clean half.
    pub kv_bytes_per_row_effective: f64,
    /// Cache rows dequantized on paged gathers (identically 0 under
    /// fp16) — the in-graph ALU work the halved HBM traffic is bought
    /// with.
    pub dequant_rows: usize,
    /// Free-list corruption events the KV pool absorbed instead of
    /// panicking: double-releases, retains/releases of free or
    /// out-of-range pages. Debug builds panic at the corrupting call,
    /// so this is only ever nonzero in release builds — and ANY
    /// nonzero value is a bug to chase with `flexllm verify`.
    pub kv_corruption_errors: usize,
    /// Page occupancy samples (pages in use / total), one per SAMPLED
    /// tick — bounded by decimation, see [`ServeMetrics::record_page_sample`].
    pub page_occupancy_s: Vec<f64>,
    /// Internal-fragmentation samples (reserved-but-unwritten row
    /// fraction across live lanes), same sampling as occupancy.
    pub page_frag_s: Vec<f64>,
    /// Sampling stride for the page vectors (every `stride`-th tick is
    /// kept; doubles whenever the buffers hit the cap).
    page_sample_stride: u64,
    /// Ticks seen since the stride last applied.
    page_sample_tick: u64,
}

/// Cap on the per-tick page-sample buffers: unlike the per-request
/// latency vectors, ticks accumulate for as long as the engine thread
/// lives, so unbounded growth would leak on a long-running Router.
const PAGE_SAMPLE_CAP: usize = 4096;

/// Drop every other sample (keeps indices 0, 2, 4, ... — an evenly
/// spread thinning used by the page-sample decimation).
fn retain_every_other(v: &mut Vec<f64>) {
    let mut keep = false;
    v.retain(|_| {
        keep = !keep;
        keep
    });
}

impl ServeMetrics {
    /// Metrics for a paged engine: records the pool size so the page
    /// accounting surface is live.
    pub fn with_pages_total(kv_pages_total: usize) -> Self {
        ServeMetrics { kv_pages_total, ..Default::default() }
    }

    /// Fold one completed request into the samples.
    pub fn record(&mut self, result: &GenResult) {
        self.requests += 1;
        self.tokens_generated += result.tokens.len();
        self.ttft_s.push(result.ttft.as_secs_f64());
        self.queue_wait_s.push(result.queue_wait.as_secs_f64());
        self.prefill_wait_s.push(result.prefill_wait().as_secs_f64());
        if result.tokens.len() > 1 {
            self.tpot_s.push(result.tpot_s());
        }
    }

    /// Merge per-shard metrics into one pool-level view by POOLING RAW
    /// SAMPLES: the latency vectors (TTFT, queue wait, prefill wait,
    /// TPOT) and the page occupancy/fragmentation vectors are
    /// concatenated, so every percentile accessor on the merged value is
    /// computed over the union of the shards' samples — never by
    /// averaging per-shard percentiles, which is not a percentile of
    /// anything (a shard with 1 sample would weigh as much as one with
    /// 10 000).
    ///
    /// Counters and durations sum. Peak gauges (`peak_active`,
    /// `kv_pages_peak`, rows reserved/written peaks) also sum: shards
    /// hit their peaks at different instants, so the summed value is a
    /// pool-level UPPER bound on simultaneous peak load, which is the
    /// honest capacity-planning number (the true simultaneous peak is
    /// not recoverable from per-shard aggregates).
    ///
    /// The merged value is a SNAPSHOT: its page-sample decimation stride
    /// resets, so keep recording into the per-shard metrics, not into a
    /// merge result.
    pub fn merge(shards: &[ServeMetrics]) -> ServeMetrics {
        let mut m = ServeMetrics::default();
        for s in shards {
            m.requests += s.requests;
            m.prefill_calls += s.prefill_calls;
            m.prefill_chunks += s.prefill_chunks;
            m.iterations += s.iterations;
            m.decode_invocations += s.decode_invocations;
            m.lane_steps += s.lane_steps;
            m.total_prefill += s.total_prefill;
            m.total_decode += s.total_decode;
            m.tokens_generated += s.tokens_generated;
            m.prefill_tokens += s.prefill_tokens;
            m.ttft_s.extend_from_slice(&s.ttft_s);
            m.queue_wait_s.extend_from_slice(&s.queue_wait_s);
            m.prefill_wait_s.extend_from_slice(&s.prefill_wait_s);
            m.tpot_s.extend_from_slice(&s.tpot_s);
            m.peak_active += s.peak_active;
            m.kv_pages_total += s.kv_pages_total;
            m.kv_pages_peak += s.kv_pages_peak;
            m.kv_pages_grown += s.kv_pages_grown;
            m.grow_failures += s.grow_failures;
            m.preemptions += s.preemptions;
            m.kv_rows_reserved_peak += s.kv_rows_reserved_peak;
            m.kv_rows_written_peak += s.kv_rows_written_peak;
            m.prefix_hits += s.prefix_hits;
            m.prefix_misses += s.prefix_misses;
            m.kv_pages_shared += s.kv_pages_shared;
            m.cow_copies += s.cow_copies;
            m.migrations_out += s.migrations_out;
            m.migrations_in += s.migrations_in;
            // codec label: keep while shards agree, degrade to "mixed"
            // the moment they don't (an unstamped shard is neutral)
            if m.kv_codec.is_empty() {
                m.kv_codec = s.kv_codec.clone();
            } else if !s.kv_codec.is_empty() && s.kv_codec != m.kv_codec {
                m.kv_codec = "mixed".to_string();
            }
            // bytes/row is a RATE, not a counter: the pool-level figure
            // is the worst shard's storage cost (max), never an average
            // of per-shard rates — averaging rates weighs a 4-page
            // shard as much as a 4096-page one
            m.kv_bytes_per_row_effective =
                m.kv_bytes_per_row_effective.max(s.kv_bytes_per_row_effective);
            m.dequant_rows += s.dequant_rows;
            m.kv_corruption_errors += s.kv_corruption_errors;
            m.page_occupancy_s.extend_from_slice(&s.page_occupancy_s);
            m.page_frag_s.extend_from_slice(&s.page_frag_s);
        }
        m
    }

    /// Aggregate decode throughput, tokens/second.
    pub fn decode_tps(&self) -> f64 {
        if self.total_decode.is_zero() {
            return 0.0;
        }
        self.tokens_generated as f64 / self.total_decode.as_secs_f64()
    }

    /// Prefill throughput, tokens/second.
    pub fn prefill_tps(&self) -> f64 {
        if self.total_prefill.is_zero() {
            return 0.0;
        }
        self.prefill_tokens as f64 / self.total_prefill.as_secs_f64()
    }

    pub fn ttft_p50(&self) -> f64 {
        percentile(&self.ttft_s, 50.0)
    }

    pub fn ttft_p95(&self) -> f64 {
        percentile(&self.ttft_s, 95.0)
    }

    pub fn tpot_p50(&self) -> f64 {
        percentile(&self.tpot_s, 50.0)
    }

    pub fn tpot_p95(&self) -> f64 {
        percentile(&self.tpot_s, 95.0)
    }

    pub fn queue_wait_p50(&self) -> f64 {
        percentile(&self.queue_wait_s, 50.0)
    }

    pub fn queue_wait_p95(&self) -> f64 {
        percentile(&self.queue_wait_s, 95.0)
    }

    pub fn prefill_wait_p50(&self) -> f64 {
        percentile(&self.prefill_wait_s, 50.0)
    }

    pub fn prefill_wait_p95(&self) -> f64 {
        percentile(&self.prefill_wait_s, 95.0)
    }

    /// Record one tick's page occupancy/fragmentation, bounded: when the
    /// buffers reach [`PAGE_SAMPLE_CAP`] they are decimated (every other
    /// sample dropped) and the sampling stride doubles, so a long-lived
    /// engine keeps an evenly spread, fixed-size history instead of an
    /// unbounded per-tick log.
    pub fn record_page_sample(&mut self, occupancy: f64, fragmentation: f64) {
        self.page_sample_tick += 1;
        if self.page_sample_tick < self.page_sample_stride.max(1) {
            return;
        }
        self.page_sample_tick = 0;
        self.page_occupancy_s.push(occupancy);
        self.page_frag_s.push(fragmentation);
        if self.page_occupancy_s.len() >= PAGE_SAMPLE_CAP {
            retain_every_other(&mut self.page_occupancy_s);
            retain_every_other(&mut self.page_frag_s);
            self.page_sample_stride = self.page_sample_stride.max(1) * 2;
        }
    }

    pub fn page_occupancy_p50(&self) -> f64 {
        percentile(&self.page_occupancy_s, 50.0)
    }

    pub fn page_occupancy_p95(&self) -> f64 {
        percentile(&self.page_occupancy_s, 95.0)
    }

    pub fn page_frag_p50(&self) -> f64 {
        percentile(&self.page_frag_s, 50.0)
    }

    pub fn page_frag_p95(&self) -> f64 {
        percentile(&self.page_frag_s, 95.0)
    }

    /// Fraction of admissions that bound a resident shared prefix; 0.0
    /// before any admission (or with sharing disabled, where neither
    /// counter moves).
    pub fn prefix_hit_rate(&self) -> f64 {
        let total = self.prefix_hits + self.prefix_misses;
        if total == 0 {
            return 0.0;
        }
        self.prefix_hits as f64 / total as f64
    }

    /// Decode lane utilization: fraction of invocation slots that
    /// carried a live request (1.0 = every slot busy every artifact
    /// call). Denominator is `decode_invocations × batch width`, so a
    /// paged tick split into several ≤batch calls is not inflated
    /// against a dense tick's single call.
    pub fn lane_utilization(&self, pool_lanes: usize) -> f64 {
        if self.decode_invocations == 0 || pool_lanes == 0 {
            return 0.0;
        }
        self.lane_steps as f64 / (self.decode_invocations * pool_lanes) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn decode_tps_counts_continuation_tokens() {
        let r = GenResult { id: 0, tokens: vec![1, 2, 3, 4, 5], ttft: Duration::ZERO,
                            queue_wait: Duration::ZERO,
                            decode_time: Duration::from_secs(2),
                            finish_reason: FinishReason::Length };
        assert!((r.decode_tps() - 2.0).abs() < 1e-9);
        assert!((r.tpot_s() - 0.5).abs() < 1e-9);
    }

    #[test]
    fn metrics_zero_safe() {
        let m = ServeMetrics::default();
        assert_eq!(m.decode_tps(), 0.0);
        assert_eq!(m.ttft_p50(), 0.0);
        assert_eq!(m.tpot_p95(), 0.0);
        assert_eq!(m.lane_utilization(4), 0.0);
    }

    #[test]
    fn percentiles_nearest_rank() {
        let samples: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert!((percentile(&samples, 50.0) - 50.0).abs() < 1e-9);
        assert!((percentile(&samples, 95.0) - 95.0).abs() < 1e-9);
        assert!((percentile(&[42.0], 95.0) - 42.0).abs() < 1e-9);
    }

    #[test]
    fn percentile_edge_ranks() {
        // q at/near the ends must clamp into the sample range, never
        // index out of bounds or return a sample that isn't there
        let two = [10.0, 20.0];
        assert!((percentile(&two, 0.0) - 10.0).abs() < 1e-9);
        assert!((percentile(&two, 1.0) - 10.0).abs() < 1e-9); // ceil(0.02)=1
        assert!((percentile(&two, 50.0) - 10.0).abs() < 1e-9); // rank 1
        assert!((percentile(&two, 51.0) - 20.0).abs() < 1e-9); // rank 2
        assert!((percentile(&two, 99.0) - 20.0).abs() < 1e-9);
        assert!((percentile(&two, 100.0) - 20.0).abs() < 1e-9);
        // unsorted input is sorted internally; q=0 stays the minimum
        let unsorted = [3.0, 1.0, 2.0];
        assert!((percentile(&unsorted, 0.0) - 1.0).abs() < 1e-9);
        assert!((percentile(&unsorted, 100.0) - 3.0).abs() < 1e-9);
        assert_eq!(percentile(&[], 50.0), 0.0);
    }

    #[test]
    fn page_sample_stride_doubles_at_each_decimation() {
        let mut m = ServeMetrics::default();
        // stride 1 until the cap: call 4096 fills the buffer and
        // decimates it to 2048, doubling the stride
        for _ in 0..PAGE_SAMPLE_CAP {
            m.record_page_sample(1.0, 0.0);
        }
        assert_eq!(m.page_occupancy_s.len(), PAGE_SAMPLE_CAP / 2);
        // stride 2: the very next tick is skipped, the one after kept
        m.record_page_sample(1.0, 0.0);
        assert_eq!(m.page_occupancy_s.len(), PAGE_SAMPLE_CAP / 2);
        m.record_page_sample(1.0, 0.0);
        assert_eq!(m.page_occupancy_s.len(), PAGE_SAMPLE_CAP / 2 + 1);
        // a second decimation doubles the stride again: after it, only
        // every 4th tick lands
        for _ in 0..(PAGE_SAMPLE_CAP - 2) {
            m.record_page_sample(1.0, 0.0);
        }
        assert_eq!(m.page_occupancy_s.len(), PAGE_SAMPLE_CAP / 2);
        for _ in 0..3 {
            m.record_page_sample(1.0, 0.0);
        }
        assert_eq!(m.page_occupancy_s.len(), PAGE_SAMPLE_CAP / 2,
                   "stride-4 decimation must skip three of four ticks");
        m.record_page_sample(1.0, 0.0);
        assert_eq!(m.page_occupancy_s.len(), PAGE_SAMPLE_CAP / 2 + 1);
        // the two buffers decimate in lockstep
        assert_eq!(m.page_occupancy_s.len(), m.page_frag_s.len());
    }

    #[test]
    fn record_accumulates_samples() {
        let mut m = ServeMetrics::default();
        m.record(&GenResult { id: 1, tokens: vec![7, 8, 9],
                              ttft: Duration::from_millis(10),
                              queue_wait: Duration::from_millis(4),
                              decode_time: Duration::from_millis(20),
                              finish_reason: FinishReason::Stop });
        assert_eq!(m.requests, 1);
        assert_eq!(m.tokens_generated, 3);
        assert_eq!(m.ttft_s.len(), 1);
        assert_eq!(m.tpot_s.len(), 1);
        assert!((m.ttft_p50() - 0.01).abs() < 1e-9);
        // queue wait + prefill wait partition the TTFT
        assert!((m.queue_wait_p50() - 0.004).abs() < 1e-9);
        assert!((m.prefill_wait_p50() - 0.006).abs() < 1e-9);
    }

    #[test]
    fn page_samples_stay_bounded_by_decimation() {
        let mut m = ServeMetrics::default();
        for i in 0..20_000 {
            m.record_page_sample(0.5 + (i % 2) as f64 * 0.1, 0.25);
        }
        // a long-lived engine must not accumulate one sample per tick
        assert!(m.page_occupancy_s.len() < PAGE_SAMPLE_CAP,
                "page samples grew unbounded: {}", m.page_occupancy_s.len());
        assert_eq!(m.page_occupancy_s.len(), m.page_frag_s.len());
        // the percentile surface stays live after decimation
        assert!(m.page_occupancy_p95() >= 0.5);
        assert!((m.page_frag_p50() - 0.25).abs() < 1e-9);
    }

    fn metrics_with_ttft(ttft: &[f64], tpot: &[f64]) -> ServeMetrics {
        ServeMetrics {
            requests: ttft.len(),
            ttft_s: ttft.to_vec(),
            tpot_s: tpot.to_vec(),
            ..ServeMetrics::default()
        }
    }

    #[test]
    fn merge_pools_raw_samples_not_percentiles() {
        // shard A: 99 fast requests; shard B: 1 slow one. Averaging the
        // per-shard p95s would yield (1.0 + 9.0) / 2 = 5.0; the pooled
        // p95 over 100 samples is 1.0 (rank 95 of 99×1.0 + 1×9.0).
        let a = metrics_with_ttft(&vec![1.0; 99], &[0.1; 4]);
        let b = metrics_with_ttft(&[9.0], &[0.5]);
        let merged = ServeMetrics::merge(&[a.clone(), b.clone()]);
        assert_eq!(merged.requests, 100);
        assert_eq!(merged.ttft_s.len(), 100);
        let mut pooled = a.ttft_s.clone();
        pooled.extend_from_slice(&b.ttft_s);
        assert!((merged.ttft_p95() - percentile(&pooled, 95.0)).abs() < 1e-12);
        assert!((merged.ttft_p95() - 1.0).abs() < 1e-12);
        let averaged = (a.ttft_p95() + b.ttft_p95()) / 2.0;
        assert!((averaged - 5.0).abs() < 1e-12,
                "the buggy formulation must actually differ for this to guard");
        assert!((merged.ttft_p95() - averaged).abs() > 1.0,
                "pooled p95 must not equal averaged per-shard p95s");
        // TPOT pools too, preserving every sample
        assert_eq!(merged.tpot_s.len(), 5);
        let mut tpot = a.tpot_s.clone();
        tpot.extend_from_slice(&b.tpot_s);
        assert!((merged.tpot_p95() - percentile(&tpot, 95.0)).abs() < 1e-12);
    }

    #[test]
    fn merge_skewed_shards_match_concatenated_percentiles() {
        // two genuinely skewed distributions: uniform 1..=50 and 51..=100
        let a = metrics_with_ttft(&(1..=50).map(f64::from).collect::<Vec<_>>(), &[]);
        let b = metrics_with_ttft(&(51..=100).map(f64::from).collect::<Vec<_>>(), &[]);
        let merged = ServeMetrics::merge(&[a, b]);
        for q in [50.0, 95.0] {
            let all: Vec<f64> = (1..=100).map(f64::from).collect();
            assert!((percentile(&merged.ttft_s, q) - percentile(&all, q)).abs() < 1e-12,
                    "merged p{q} must equal the percentile of the concatenation");
        }
        assert!((merged.ttft_p50() - 50.0).abs() < 1e-12);
        assert!((merged.ttft_p95() - 95.0).abs() < 1e-12);
    }

    #[test]
    fn merge_edge_cases_empty_and_single_sample_shards() {
        // no shards → zero-safe default
        let empty = ServeMetrics::merge(&[]);
        assert_eq!(empty.requests, 0);
        assert_eq!(empty.ttft_p95(), 0.0);
        // an EMPTY shard merged beside a live one must not perturb it
        let lone = metrics_with_ttft(&[2.0], &[0.25]);
        let merged = ServeMetrics::merge(&[ServeMetrics::default(), lone.clone()]);
        assert_eq!(merged.requests, 1);
        assert!((merged.ttft_p50() - 2.0).abs() < 1e-12);
        assert!((merged.ttft_p95() - 2.0).abs() < 1e-12);
        assert!((merged.tpot_p95() - 0.25).abs() < 1e-12);
        // single-sample shards pool into an exact two-point distribution
        let merged = ServeMetrics::merge(&[lone, metrics_with_ttft(&[4.0], &[])]);
        assert_eq!(merged.ttft_s.len(), 2);
        assert!((merged.ttft_p50() - 2.0).abs() < 1e-12);
        assert!((merged.ttft_p95() - 4.0).abs() < 1e-12);
        // merging ONE shard reproduces its sample surface verbatim
        let solo = metrics_with_ttft(&[1.0, 3.0, 5.0], &[0.1, 0.2]);
        let merged = ServeMetrics::merge(&[solo.clone()]);
        assert_eq!(merged.ttft_s, solo.ttft_s);
        assert_eq!(merged.tpot_s, solo.tpot_s);
        assert_eq!(merged.requests, solo.requests);
    }

    #[test]
    fn merge_sums_counters_and_peak_gauges() {
        let mut a = ServeMetrics::with_pages_total(20);
        a.iterations = 10;
        a.decode_invocations = 12;
        a.lane_steps = 40;
        a.peak_active = 6;
        a.kv_pages_peak = 18;
        a.kv_pages_grown = 3;
        a.preemptions = 1;
        a.tokens_generated = 100;
        a.total_decode = Duration::from_secs(2);
        a.record_page_sample(0.5, 0.1);
        let mut b = ServeMetrics::with_pages_total(20);
        b.iterations = 4;
        b.decode_invocations = 4;
        b.lane_steps = 8;
        b.peak_active = 2;
        b.kv_pages_peak = 7;
        b.grow_failures = 2;
        b.tokens_generated = 50;
        b.total_decode = Duration::from_secs(1);
        b.record_page_sample(0.25, 0.3);
        let m = ServeMetrics::merge(&[a, b]);
        assert_eq!(m.kv_pages_total, 40);
        assert_eq!(m.iterations, 14);
        assert_eq!(m.decode_invocations, 16);
        assert_eq!(m.lane_steps, 48);
        assert_eq!(m.peak_active, 8, "peaks sum to the pool-level upper bound");
        assert_eq!(m.kv_pages_peak, 25);
        assert_eq!(m.kv_pages_grown, 3);
        assert_eq!(m.grow_failures, 2);
        assert_eq!(m.preemptions, 1);
        assert_eq!(m.tokens_generated, 150);
        assert_eq!(m.total_decode, Duration::from_secs(3));
        // page samples pooled, percentile surface live
        assert_eq!(m.page_occupancy_s.len(), 2);
        assert!((m.page_occupancy_p95() - 0.5).abs() < 1e-12);
        assert!((m.page_frag_p95() - 0.3).abs() < 1e-12);
        // decode_tps over the merged totals
        assert!((m.decode_tps() - 50.0).abs() < 1e-9);
    }

    #[test]
    fn merge_sums_prefix_share_and_migration_counters() {
        // the shared-prefix counters (PR 6) and the migration counters
        // (PR 7) pool by summation: a hit rate computed on the merged
        // value must equal the pool-level hits / (hits + misses), and a
        // shard that recorded nothing must not perturb the sums
        let mut a = ServeMetrics::default();
        a.prefix_hits = 6;
        a.prefix_misses = 2;
        a.kv_pages_shared = 18;
        a.cow_copies = 3;
        a.migrations_out = 5;
        let mut b = ServeMetrics::default();
        b.prefix_hits = 2;
        b.prefix_misses = 6;
        b.kv_pages_shared = 4;
        b.cow_copies = 1;
        b.migrations_in = 5;
        let m = ServeMetrics::merge(&[a.clone(), ServeMetrics::default(), b.clone()]);
        assert_eq!(m.prefix_hits, 8);
        assert_eq!(m.prefix_misses, 8);
        assert_eq!(m.kv_pages_shared, 22);
        assert_eq!(m.cow_copies, 4);
        assert_eq!(m.migrations_out, 5);
        assert_eq!(m.migrations_in, 5);
        assert!((m.prefix_hit_rate() - 0.5).abs() < 1e-12,
                "pool hit rate must come from pooled counters, not an \
                 average of per-shard rates");
        // per-shard rates straddle the pooled value (0.75 and 0.25), so
        // an averaged-rate bug would happen to match 0.5 here — pin the
        // counter sums above, and pin asymmetry with a lopsided merge
        let m = ServeMetrics::merge(&[a, ServeMetrics::default()]);
        assert!((m.prefix_hit_rate() - 0.75).abs() < 1e-12);
        assert_eq!(m.kv_pages_shared, 18);
        assert_eq!(m.migrations_out, 5);
        assert_eq!(m.migrations_in, 0);
    }

    #[test]
    fn merge_pools_kv_codec_and_dequant_counters() {
        // PR 8: dequant rows SUM, bytes/row takes the pool-level MAX
        // (same averaging guard as the percentile merge: averaging
        // per-shard rates is not a rate of anything), and the codec
        // label survives agreement but degrades to "mixed" on conflict
        let mut a = ServeMetrics::default();
        a.kv_codec = "int8".to_string();
        a.kv_bytes_per_row_effective = 1.125;
        a.dequant_rows = 640;
        let mut b = ServeMetrics::default();
        b.kv_codec = "int8".to_string();
        b.kv_bytes_per_row_effective = 1.125;
        b.dequant_rows = 360;
        let m = ServeMetrics::merge(&[a.clone(), b.clone()]);
        assert_eq!(m.kv_codec, "int8", "agreeing shards keep their codec");
        assert_eq!(m.dequant_rows, 1000);
        assert!((m.kv_bytes_per_row_effective - 1.125).abs() < 1e-12);
        // an UNSTAMPED (default) shard must not perturb the label
        let m = ServeMetrics::merge(&[ServeMetrics::default(), a.clone()]);
        assert_eq!(m.kv_codec, "int8");
        assert_eq!(m.dequant_rows, 640);
        // codec conflict → "mixed"; bytes/row is the max, NOT the mean
        let mut fp = ServeMetrics::default();
        fp.kv_codec = "fp16".to_string();
        fp.kv_bytes_per_row_effective = 2.0;
        let m = ServeMetrics::merge(&[a, fp]);
        assert_eq!(m.kv_codec, "mixed",
                   "a pool-level metric must not claim a codec half its \
                    shards don't run");
        assert!((m.kv_bytes_per_row_effective - 2.0).abs() < 1e-12);
        let averaged = (1.125 + 2.0) / 2.0;
        assert!((m.kv_bytes_per_row_effective - averaged).abs() > 0.2,
                "merged bytes/row must not equal averaged per-shard rates");
        // merge order must not change the verdict
        let mut c = ServeMetrics::default();
        c.kv_codec = "fp16".to_string();
        let mut d = ServeMetrics::default();
        d.kv_codec = "int8".to_string();
        assert_eq!(ServeMetrics::merge(&[c.clone(), d.clone()]).kv_codec, "mixed");
        assert_eq!(ServeMetrics::merge(&[d, c]).kv_codec, "mixed");
    }

    #[test]
    fn stop_tokens_builder() {
        let r = GenRequest::new(1, vec![0; 4], 8).with_stop_tokens(vec![2]);
        assert_eq!(r.stop_tokens, vec![2]);
        assert!(GenRequest::new(1, vec![], 1).stop_tokens.is_empty());
    }

    #[test]
    fn slo_defaults_to_batch_and_builds() {
        use crate::coordinator::frontdoor::SloClass;
        let r = GenRequest::new(1, vec![0; 4], 8);
        assert_eq!(r.slo.class, SloClass::Batch, "unmarked traffic is batch");
        let r = r.with_slo(Slo::interactive().with_ttft_deadline(0.5));
        assert_eq!(r.slo.class, SloClass::Interactive);
        assert!((r.slo.ttft_deadline_s - 0.5).abs() < 1e-12);
    }
}
