//! FlexLLM leader binary: report generation, serving, ablation, DSE.
//!
//! ```text
//! flexllm report [--table N] [--fig N] [--all] [--csv PATH] [--artifacts DIR]
//! flexllm serve [--requests N] [--new-tokens N] [--artifacts DIR]
//! flexllm ablate [--artifacts DIR]
//! flexllm dse [--device u280|v80] [--stage prefill|decode] [--prefill N] [--decode N]
//! flexllm simulate [--device u280|v80] [--stage prefill|decode] [--tokens N]
//! ```
//!
//! (CLI is hand-rolled: the offline vendored crate set has no clap.)

use anyhow::{anyhow, bail, Result};

use flexllm::arch::{AcceleratorSystem, DecodeArch, PrefillArch};
use flexllm::config::{DeviceConfig, ModelDims};
use flexllm::coordinator::{GenRequest, Router};
use flexllm::eval;
use flexllm::report::fmt_secs;
use flexllm::runtime::Runtime;

const USAGE: &str = "\
FlexLLM reproduction — stage-customized hybrid LLM accelerator design

USAGE:
  flexllm report [--table N] [--fig N] [--all] [--csv PATH] [--artifacts DIR]
      Regenerate paper tables (1-6) and figures (1,2,6,7,8).
  flexllm serve [--requests N] [--new-tokens N] [--artifacts DIR]
      Serve batched generation requests through the AOT artifacts.
  flexllm ablate [--artifacts DIR]
      Run the Table V quantization ablation on the real artifacts.
  flexllm dse [--device u280|v80] [--stage prefill|decode] [--prefill N] [--decode N]
      ILP-style design-space exploration for TP/WP/BP.
  flexllm simulate [--device u280|v80] [--stage prefill|decode] [--tokens N]
      Run the dataflow pipeline simulator on a stage architecture.
";

/// Minimal flag parser: --key value pairs plus boolean --flags.
struct Args {
    flags: Vec<(String, Option<String>)>,
}

impl Args {
    fn parse(argv: &[String], bools: &[&str]) -> Result<Args> {
        let mut flags = Vec::new();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            let key = a
                .strip_prefix("--")
                .ok_or_else(|| anyhow!("unexpected argument '{a}'\n\n{USAGE}"))?;
            if bools.contains(&key) {
                flags.push((key.to_string(), None));
                i += 1;
            } else {
                let v = argv
                    .get(i + 1)
                    .ok_or_else(|| anyhow!("--{key} needs a value"))?;
                flags.push((key.to_string(), Some(v.clone())));
                i += 2;
            }
        }
        Ok(Args { flags })
    }

    fn has(&self, key: &str) -> bool {
        self.flags.iter().any(|(k, _)| k == key)
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.flags
            .iter()
            .find(|(k, _)| k == key)
            .and_then(|(_, v)| v.as_deref())
    }

    fn get_u64(&self, key: &str, default: u64) -> Result<u64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow!("--{key}: bad number '{v}'")),
        }
    }

    fn get_str(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }
}

fn device_of(name: &str) -> Result<DeviceConfig> {
    match name {
        "u280" => Ok(DeviceConfig::u280()),
        "v80" => Ok(DeviceConfig::v80()),
        other => bail!("unknown device '{other}' (u280|v80)"),
    }
}

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = argv.first() else {
        print!("{USAGE}");
        return Ok(());
    };
    let rest = &argv[1..];
    match cmd.as_str() {
        "report" => {
            let a = Args::parse(rest, &["all"])?;
            report(&a)
        }
        "serve" => {
            let a = Args::parse(rest, &[])?;
            serve(
                a.get_u64("requests", 8)? as usize,
                a.get_u64("new-tokens", 32)? as usize,
                &a.get_str("artifacts", "artifacts"),
            )
        }
        "ablate" => {
            let a = Args::parse(rest, &[])?;
            let rt = Runtime::open(a.get_str("artifacts", "artifacts"))?;
            println!("{}", eval::table5(&rt)?);
            Ok(())
        }
        "dse" => {
            let a = Args::parse(rest, &[])?;
            dse(
                &a.get_str("device", "u280"),
                &a.get_str("stage", "decode"),
                a.get_u64("prefill", 1024)?,
                a.get_u64("decode", 1024)?,
            )
        }
        "simulate" => {
            let a = Args::parse(rest, &[])?;
            simulate(
                &a.get_str("device", "u280"),
                &a.get_str("stage", "prefill"),
                a.get_u64("tokens", 1024)?,
            )
        }
        "help" | "--help" | "-h" => {
            print!("{USAGE}");
            Ok(())
        }
        other => bail!("unknown command '{other}'\n\n{USAGE}"),
    }
}

fn report(a: &Args) -> Result<()> {
    let all = a.has("all");
    let artifacts = a.get_str("artifacts", "artifacts");
    let tables: Vec<u64> = if all {
        vec![1, 2, 3, 4, 5, 6]
    } else {
        a.get("table").map(|v| v.parse()).transpose()?.into_iter().collect()
    };
    let figs: Vec<u64> = if all {
        vec![1, 2, 6, 7, 8]
    } else {
        a.get("fig").map(|v| v.parse()).transpose()?.into_iter().collect()
    };
    let mut printed = false;
    for t in tables {
        printed = true;
        match t {
            1 => println!("{}", eval::table1()),
            2 => println!("{}", eval::table2()),
            3 => println!("{}", eval::table3()),
            4 => {
                let (py, rs) = count_loc();
                println!("{}", eval::table4(py, rs));
            }
            5 => {
                let rt = Runtime::open(&artifacts)?;
                println!("{}", eval::table5(&rt)?);
            }
            6 => println!("{}", eval::table6()),
            _ => bail!("no table {t} in the paper"),
        }
    }
    for f in figs {
        printed = true;
        match f {
            1 => println!("{}", eval::fig1()),
            2 => println!("{}", eval::fig2()),
            6 => println!("{}", eval::fig6()),
            7 => println!("{}", eval::fig7()),
            8 => println!("{}", eval::fig8()),
            _ => bail!("figure {f} is schematic-only in the paper (1,2,6,7,8 supported)"),
        }
    }
    if let Some(path) = a.get("csv") {
        std::fs::write(path, eval::fig7_csv())?;
        println!("wrote Fig. 7 series to {path}");
        printed = true;
    }
    if !printed {
        bail!("nothing to report: pass --table N, --fig N or --all");
    }
    Ok(())
}

fn serve(n_requests: usize, new_tokens: usize, artifacts: &str) -> Result<()> {
    let rt = Runtime::open(artifacts)?;
    let s = rt.manifest.serving.prefill_len;
    let bytes = std::fs::read(rt.dir().join("prompt_tokens.bin"))?;
    let toks: Vec<i32> = bytes
        .chunks_exact(4)
        .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect();
    let base: Vec<Vec<i32>> = toks.chunks_exact(s).map(|c| c.to_vec()).collect();
    drop(rt);

    let router = Router::spawn(artifacts.to_string())?;
    let queue: Vec<GenRequest> = (0..n_requests)
        .map(|i| GenRequest {
            id: i as u64,
            prompt: base[i % base.len()].clone(),
            max_new_tokens: new_tokens,
        })
        .collect();

    let t0 = std::time::Instant::now();
    let results = router.generate(queue)?;
    let wall = t0.elapsed();
    let m = router.metrics()?;
    println!("served {} requests in {}", results.len(), fmt_secs(wall.as_secs_f64()));
    println!("  prefill: {} tok/s   decode: {:.1} tok/s   mean batch latency {}",
             m.prefill_tps() as u64, m.decode_tps(),
             fmt_secs(m.mean_batch_latency().as_secs_f64()));
    for r in results.iter().take(2) {
        println!("  req {}: ttft {} first tokens {:?}",
                 r.id, fmt_secs(r.ttft.as_secs_f64()), &r.tokens[..r.tokens.len().min(8)]);
    }
    Ok(())
}

fn dse(device: &str, stage: &str, prefill: u64, decode: u64) -> Result<()> {
    let model = ModelDims::llama32_1b();
    let dev = device_of(device)?;
    match stage {
        "prefill" => {
            let r = flexllm::dse::tune_prefill(&model, &dev, prefill);
            println!("prefill DSE on {}: best TP={} WPkqvo={} WPmha={} WPffn={} → {}",
                     dev.name, r.best.tp, r.best.wp_kqvo, r.best.wp_mha, r.best.wp_ffn,
                     fmt_secs(r.latency_s));
            println!("  evaluated {} candidates, {} feasible", r.evaluated, r.feasible);
            let arch = PrefillArch::new(r.best, model, dev);
            println!("  binding util {:.1}%  peak BW {:.0} GB/s",
                     arch.utilization().max_class() * 100.0,
                     arch.peak_bandwidth() / 1e9);
        }
        "decode" => {
            let r = flexllm::dse::tune_decode(&model, &dev, prefill, decode);
            println!("decode DSE on {}: best BP={} WPint4={} WPmha={} → {}",
                     dev.name, r.best.bp, r.best.wp_int4, r.best.wp_mha,
                     fmt_secs(r.latency_s));
            println!("  evaluated {} candidates, {} feasible", r.evaluated, r.feasible);
            let arch = DecodeArch::new(r.best, model, dev);
            println!("  binding util {:.1}%  peak BW {:.0} GB/s  partitions {}",
                     arch.utilization().max_class() * 100.0,
                     arch.peak_bandwidth() / 1e9, arch.partitions);
        }
        other => bail!("unknown stage '{other}' (prefill|decode)"),
    }
    Ok(())
}

fn simulate(device: &str, stage: &str, tokens: u64) -> Result<()> {
    let sys = match device {
        "u280" => AcceleratorSystem::u280(),
        "v80" => AcceleratorSystem::v80(),
        other => bail!("unknown device '{other}' (u280|v80)"),
    };
    match stage {
        "prefill" => {
            let r = sys.prefill.simulate(tokens);
            println!("prefill sim ({} tokens/layer): {:.0} cycles/layer, mean util {:.1}%",
                     tokens, r.makespan_cycles, r.mean_utilization * 100.0);
            println!("  analytic {}  simulated {}",
                     fmt_secs(sys.prefill.analytic_latency_s(tokens)),
                     fmt_secs(sys.prefill.simulated_latency_s(tokens)));
            for n in &r.nodes {
                println!("  {:<24} busy {:>12.0}  stall {:>12.0}  util {:>5.1}%",
                         n.name, n.busy_cycles, n.stall_cycles, n.utilization * 100.0);
            }
        }
        "decode" => {
            let r = sys.decode.simulate(1024, tokens);
            println!("decode sim ({} tokens): {:.0} cycles, mean util {:.1}%",
                     tokens, r.makespan_cycles, r.mean_utilization * 100.0);
            println!("  analytic {}  simulated {}",
                     fmt_secs(sys.decode.analytic_latency_s(1024, tokens)),
                     fmt_secs(sys.decode.simulated_latency_s(1024, tokens)));
        }
        other => bail!("unknown stage '{other}' (prefill|decode)"),
    }
    Ok(())
}

/// Rough LoC counter for Table IV (this repo's own code sizes).
fn count_loc() -> (usize, usize) {
    fn count_dir(dir: &str, ext: &str) -> usize {
        let mut total = 0;
        let mut stack = vec![std::path::PathBuf::from(dir)];
        while let Some(d) = stack.pop() {
            let Ok(entries) = std::fs::read_dir(&d) else { continue };
            for e in entries.flatten() {
                let p = e.path();
                if p.is_dir() {
                    stack.push(p);
                } else if p.extension().map(|x| x == ext).unwrap_or(false) {
                    if let Ok(s) = std::fs::read_to_string(&p) {
                        total += s.lines().filter(|l| !l.trim().is_empty()).count();
                    }
                }
            }
        }
        total
    }
    (count_dir("python", "py"), count_dir("rust", "rs") + count_dir("examples", "rs"))
}
