//! FlexLLM leader binary: report generation, serving, ablation, DSE.
//!
//! ```text
//! flexllm report [--table N] [--fig N] [--all] [--csv PATH] [--artifacts DIR]
//! flexllm serve [--requests N] [--new-tokens N] [--spread K] [--arrival-rate R]
//!               [--stream] [--stop-token T] [--backend pjrt|mock|modeled]
//!               [--prefill-policy blocking|chunked] [--prefill-chunk C|adaptive]
//!               [--prefill-greedy] [--kv-pages P] [--page-len L]
//!               [--kv-reserve upfront|lazy] [--kv-overcommit F]
//!               [--kv-quant fp16|int8]
//!               [--prefix-share] [--shared-prefix-len N]
//!               [--slo interactive|batch] [--shed-watermark F] [--steal]
//!               [--shards N] [--shard-roles SPEC] [--artifacts DIR]
//! flexllm ablate [--artifacts DIR]
//! flexllm dse [--device u280|v80] [--stage prefill|decode|shard-mix]
//!             [--prefill N] [--decode N] [--shards N] [--rate R]
//! flexllm simulate [--device u280|v80] [--stage prefill|decode] [--tokens N]
//! flexllm verify [--bounded] [--arch-lint] [--depth N] [--config NAME]
//!                [--replay SPEC] [--trace-out PATH]
//! ```
//!
//! (CLI is hand-rolled: the offline vendored crate set has no clap.)

use std::collections::VecDeque;

use flexllm::anyhow::{anyhow, bail, Result};

use flexllm::arch::{AcceleratorSystem, DecodeArch, PrefillArch};
use flexllm::config::{DeviceConfig, ModelDims};
use flexllm::coordinator::{overflow_insert, pick_donor, place_migration,
                           place_shard, place_shard_affine, split_budget, Engine,
                           ExecBackend, FrontDoorConfig, GenRequest, GenResult,
                           KvLayout, MigratedLane, MockBackend, ModeledBackend,
                           PageCodec, PoolSnapshot, PrefillPolicy,
                           ReservationPolicy, RouterBuilder, ServeConfig,
                           ServeMetrics, ShardRole, Slo, SloClass,
                           TopologyConfig};
use flexllm::eval;
use flexllm::report::fmt_secs;
use flexllm::runtime::Runtime;

const USAGE: &str = "\
FlexLLM reproduction — stage-customized hybrid LLM accelerator design

USAGE:
  flexllm report [--table N] [--fig N] [--all] [--csv PATH] [--artifacts DIR]
      Regenerate paper tables (1-6) and figures (1,2,6,7,8).
  flexllm serve [--requests N] [--new-tokens N] [--spread K] [--arrival-rate R]
                [--stream] [--stop-token T] [--backend pjrt|mock|modeled]
                [--prefill-policy blocking|chunked] [--prefill-chunk C|adaptive]
                [--prefill-greedy] [--kv-pages P] [--page-len L]
                [--kv-reserve upfront|lazy] [--kv-overcommit F]
                [--kv-quant fp16|int8]
                [--prefix-share] [--shared-prefix-len N]
                [--slo interactive|batch] [--shed-watermark F] [--steal]
                [--shards N] [--shard-roles SPEC] [--artifacts DIR]
      Serve generation requests through the iteration-level scheduler.
      --spread K        skew budgets: request i gets ~new-tokens·(i%K+1)/K
      --arrival-rate R  stagger submissions at R req/s (pjrt backend)
      --stream          print every token as it is generated
      --stop-token T    stop lanes early when token T is produced
      --backend         pjrt (AOT artifacts, default), mock (deterministic,
                        artifact-free) or modeled (mock tokens + pipeline-sim
                        hardware clocks of the paper's U280 stage engines)
      --prefill-policy  blocking (whole-pool admission prefill, default) or
                        chunked (prompts stream in chunks interleaved with
                        decode iterations — cuts TTFT tail under load)
      --prefill-chunk C prompt tokens per chunk: a count pins the static
                        ladder, \"adaptive\" (the default when chunked)
                        resizes every admission chunk from live pool
                        pressure — a backlog doubles the width toward
                        128, an empty queue halves it toward 8 (the pjrt
                        backend snaps chunks to the compiled width)
      --prefill-greedy  feed every prefilling lane a chunk per tick instead
                        of one per tick (drains admissions faster, decode
                        lanes pay)
      --kv-pages P      serve over a PAGED KV pool of P shared pages instead
                        of dense max_seq-per-lane rows: short requests free
                        memory early and admission is bounded by free pages,
                        not lanes. P=0 defaults to the dense pool's memory
                        budget (pjrt: geometry comes from the artifact
                        manifest; the flag selects the layout only)
      --page-len L      cache rows per page for mock/modeled paged pools
                        (default 64, must tile max_seq 320; pjrt uses the
                        artifact page size)
      --kv-reserve      upfront (whole-budget page reservation at admission,
                        default) or lazy (admission backs only the prompt
                        plus one decode slot; pages grow on demand and a dry
                        pool preempts the youngest request, which recomputes
                        from the queue head — streams stay byte-identical)
      --kv-overcommit F shrink the mock/modeled paged pool to 1/F of the
                        dense memory budget (default 1; needs --kv-reserve
                        lazy to be useful — upfront admission just queues)
      --kv-quant        fp16 (identity storage, default) or int8: store K/V
                        page rows as symmetric INT8 with a per-page scale
                        header, quantized on the scatter path and
                        dequantized in-graph on gather. The same page
                        memory then holds 2x the pages (mock/modeled size
                        the default pool accordingly; pjrt needs a *_kv8
                        artifact set). Needs the paged layout
      --prefix-share    admit requests whose page-aligned prompt prefix is
                        already resident in the paged pool with ZERO prefill
                        work for the shared span: pages are refcounted and
                        shared read-only across lanes, divergent tails fork
                        copy-on-write, and sharded placement prefers the
                        shard holding the prefix (needs the paged layout)
      --shared-prefix-len N
                        give every synthetic sim request the same N-token
                        prompt head (a "system prompt"), the workload the
                        prefix cache feeds on (mock/modeled; pjrt prompts
                        come from the artifact set and repeat on their own)
      --shards N        serve over N engine shards: each shard owns its
                        own scheduler, KV pool and backend instance, and
                        requests go to the shard with the most free pages
                        (FIFO overflow when all are starved). mock/modeled
                        split the KV budget evenly across shards at equal
                        total memory; pjrt opens one artifact set (device)
                        per shard via the threaded Router
      --shard-roles SPEC
                        disaggregate the pool: a comma list of roles, each
                        optionally repeat-counted — \"2p,2d\", \"1p,1d\",
                        \"prefill,decode,unified\". Prefill shards admit and
                        prefill only; at its first token a request's KV
                        page table migrates to the least-loaded decode
                        shard (the modeled page transfer is priced before
                        the first decode tick). Overrides --shards; needs
                        the paged layout
      --slo CLASS       SLO class stamped on every synthetic sim request:
                        batch (default; loose deadlines, sheddable past
                        the watermark) or interactive (tight deadlines,
                        admitted ahead of queued Batch, never shed)
      --shed-watermark F
                        turn the SLO front door ON: Batch arrivals are
                        shed once pool-wide queued page demand exceeds
                        F x the total pool (F > 1 tolerates that much
                        queueing; default 0.75). Dense layouts have no
                        page pool and never shed
      --steal           turn the front door ON with cross-shard work
                        stealing: a hungry shard (a free lane, nothing
                        of its own queued) pulls the youngest queued,
                        never-prefilled request from the deepest
                        per-shard queue (needs --shards > 1)
      Examples:
        flexllm serve --backend modeled --requests 32 --spread 4 \
                      --prefill-policy chunked --prefill-chunk 32
        flexllm serve --backend pjrt --arrival-rate 8 --prefill-policy chunked
        flexllm serve --backend modeled --requests 64 --spread 8 \
                      --kv-pages 20 --page-len 64
                      # paged pool: compare the "kv pages" line and peak
                      # concurrency against the dense default
        flexllm serve --backend modeled --requests 64 --spread 8 \
                      --page-len 32 --kv-reserve lazy --kv-overcommit 2
                      # lazy growth on half the memory: watch pages grown,
                      # preemptions and the fragmentation percentiles
        flexllm serve --backend modeled --requests 64 --spread 8 \
                      --kv-pages 40 --page-len 32 --shards 2
                      # two engine shards on the same total memory: the
                      # per-shard lines show the free-page balancing
        flexllm serve --backend modeled --requests 64 --kv-pages 40 \
                      --page-len 32 --prefix-share --shared-prefix-len 96
                      # shared-prefix cache: compare the prefix hit rate
                      # and ttft against the same run without the flag
        flexllm serve --backend modeled --requests 64 --spread 8 \
                      --page-len 32 --kv-quant int8
                      # int8 KV pages: same memory, double the pages —
                      # compare peak concurrency and the dequant rows
                      # line against the fp16 default
        flexllm serve --backend modeled --requests 64 --spread 8 \
                      --kv-pages 40 --page-len 32 --shards 2 \
                      --shed-watermark 1.5 --steal
                      # SLO front door on an overloaded 2-shard pool:
                      # the front-door line reports shed and stolen
                      # counts next to the per-shard balance
  flexllm ablate [--artifacts DIR]
      Run the Table V quantization ablation on the real artifacts.
  flexllm dse [--device u280|v80] [--stage prefill|decode|shard-mix]
              [--prefill N] [--decode N] [--shards N] [--rate R]
      ILP-style design-space exploration for TP/WP/BP — or, with
      --stage shard-mix, sweep every prefill/decode shard split up to
      --shards total shards (default 2) on a prefill-heavy Poisson
      open-loop workload at --rate req/s (default 12) and equal total
      KV memory, reporting the best mixed vs best homogeneous topology.
  flexllm simulate [--device u280|v80] [--stage prefill|decode] [--tokens N]
      Run the dataflow pipeline simulator on a stage architecture.
  flexllm verify [--bounded] [--arch-lint] [--depth N] [--config NAME]
                 [--replay SPEC] [--trace-out PATH]
      Check the KV page/refcount/migration state machine and the crate's
      architectural rules. With no mode flag BOTH gates run. Any
      violation prints a minimized, replayable counterexample and the
      command exits nonzero (the CI gate).
      --bounded         bounded exhaustive model check: drive the real
                        scheduler + paged KV pool through every
                        interleaving of the first --depth scheduling
                        decisions (arrival order, tick order, migration
                        timing) across the 16-cell {upfront,lazy} ×
                        {share,noshare} × {unified,disagg} × {fp16,int8}
                        matrix, asserting the verify::invariants
                        predicates after every step
      --arch-lint       dependency-free source lint over rust/src: pool
                        alloc/release/retain stay inside kv.rs and
                        scheduler.rs, no pool-array indexing outside
                        kv.rs, no unwrap/expect in the coordinator
                        facade, every public coordinator type is Debug
      --depth N         choice points explored exhaustively per episode
                        (default 6; deeper decisions take the first
                        enabled action)
      --config NAME     restrict --bounded to one matrix cell, e.g.
                        lazy-share-disagg-int8
      --replay SPEC     re-run one recorded trace deterministically,
                        e.g. \"lazy-share-disagg-int8:0,2,1\" (the spec
                        printed with every counterexample)
      --trace-out PATH  write the replay specs of any counterexamples
                        to PATH (one per line; CI uploads it)
";

/// Minimal flag parser: --key value pairs plus boolean --flags.
struct Args {
    flags: Vec<(String, Option<String>)>,
}

impl Args {
    fn parse(argv: &[String], bools: &[&str]) -> Result<Args> {
        let mut flags = Vec::new();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            let key = a
                .strip_prefix("--")
                .ok_or_else(|| anyhow!("unexpected argument '{a}'\n\n{USAGE}"))?;
            if bools.contains(&key) {
                flags.push((key.to_string(), None));
                i += 1;
            } else {
                let v = argv
                    .get(i + 1)
                    .ok_or_else(|| anyhow!("--{key} needs a value"))?;
                flags.push((key.to_string(), Some(v.clone())));
                i += 2;
            }
        }
        Ok(Args { flags })
    }

    fn has(&self, key: &str) -> bool {
        self.flags.iter().any(|(k, _)| k == key)
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.flags
            .iter()
            .find(|(k, _)| k == key)
            .and_then(|(_, v)| v.as_deref())
    }

    fn get_u64(&self, key: &str, default: u64) -> Result<u64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow!("--{key}: bad number '{v}'")),
        }
    }

    fn get_str(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    fn get_f64(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow!("--{key}: bad number '{v}'")),
        }
    }
}

fn device_of(name: &str) -> Result<DeviceConfig> {
    match name {
        "u280" => Ok(DeviceConfig::u280()),
        "v80" => Ok(DeviceConfig::v80()),
        other => bail!("unknown device '{other}' (u280|v80)"),
    }
}

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = argv.first() else {
        print!("{USAGE}");
        return Ok(());
    };
    let rest = &argv[1..];
    match cmd.as_str() {
        "report" => {
            let a = Args::parse(rest, &["all"])?;
            report(&a)
        }
        "serve" => {
            let a = Args::parse(rest,
                                &["stream", "prefill-greedy", "prefix-share",
                                  "steal"])?;
            serve(&a)
        }
        "ablate" => {
            let a = Args::parse(rest, &[])?;
            let rt = Runtime::open(a.get_str("artifacts", "artifacts"))?;
            println!("{}", eval::table5(&rt)?);
            Ok(())
        }
        "dse" => {
            let a = Args::parse(rest, &[])?;
            dse(
                &a.get_str("device", "u280"),
                &a.get_str("stage", "decode"),
                a.get_u64("prefill", 1024)?,
                a.get_u64("decode", 1024)?,
                a.get_u64("shards", 2)?.max(2) as usize,
                a.get_f64("rate", 12.0)?,
            )
        }
        "simulate" => {
            let a = Args::parse(rest, &[])?;
            simulate(
                &a.get_str("device", "u280"),
                &a.get_str("stage", "prefill"),
                a.get_u64("tokens", 1024)?,
            )
        }
        "verify" => {
            let a = Args::parse(rest, &["bounded", "arch-lint"])?;
            verify(&a)
        }
        "help" | "--help" | "-h" => {
            print!("{USAGE}");
            Ok(())
        }
        other => bail!("unknown command '{other}'\n\n{USAGE}"),
    }
}

fn report(a: &Args) -> Result<()> {
    let all = a.has("all");
    let artifacts = a.get_str("artifacts", "artifacts");
    let tables: Vec<u64> = if all {
        vec![1, 2, 3, 4, 5, 6]
    } else {
        a.get("table").map(|v| v.parse()).transpose()?.into_iter().collect()
    };
    let figs: Vec<u64> = if all {
        vec![1, 2, 6, 7, 8]
    } else {
        a.get("fig").map(|v| v.parse()).transpose()?.into_iter().collect()
    };
    let mut printed = false;
    for t in tables {
        printed = true;
        match t {
            1 => println!("{}", eval::table1()),
            2 => println!("{}", eval::table2()),
            3 => println!("{}", eval::table3()),
            4 => {
                let (py, rs) = count_loc();
                println!("{}", eval::table4(py, rs));
            }
            5 => {
                let rt = Runtime::open(&artifacts)?;
                println!("{}", eval::table5(&rt)?);
            }
            6 => println!("{}", eval::table6()),
            _ => bail!("no table {t} in the paper"),
        }
    }
    for f in figs {
        printed = true;
        match f {
            1 => println!("{}", eval::fig1()),
            2 => println!("{}", eval::fig2()),
            6 => println!("{}", eval::fig6()),
            7 => println!("{}", eval::fig7()),
            8 => println!("{}", eval::fig8()),
            _ => bail!("figure {f} is schematic-only in the paper (1,2,6,7,8 supported)"),
        }
    }
    if let Some(path) = a.get("csv") {
        std::fs::write(path, eval::fig7_csv())?;
        println!("wrote Fig. 7 series to {path}");
        printed = true;
    }
    if !printed {
        bail!("nothing to report: pass --table N, --fig N or --all");
    }
    Ok(())
}

/// Per-request generation budget under `--spread K` skew: request `i`
/// gets roughly `new_tokens · (i % K + 1) / K` tokens, so a K=4 spread
/// covers a 4× range — the workload where iteration-level scheduling
/// beats max-aligned batching hardest.
fn skewed_budget(i: usize, new_tokens: usize, spread: usize) -> usize {
    if spread <= 1 {
        return new_tokens.max(1);
    }
    (new_tokens * (i % spread + 1) / spread).max(1)
}

/// Parse `--prefill-policy` / `--prefill-chunk` / `--prefill-greedy`.
/// `--prefill-chunk` takes a token count or the literal `adaptive`,
/// and since PR 10 adaptive IS the chunked default: the scheduler
/// resizes every admission chunk from live pool pressure instead of a
/// static knob the operator has to tune per workload.
fn prefill_policy(a: &Args) -> Result<PrefillPolicy> {
    let decode_priority = !a.has("prefill-greedy");
    match a.get_str("prefill-policy", "blocking").as_str() {
        "blocking" => Ok(PrefillPolicy::Blocking),
        "chunked" => match a.get("prefill-chunk") {
            // bounds span the sim prompt: halve toward 8 when idle,
            // double toward the full 128-token prompt under backlog
            None | Some("adaptive") => Ok(PrefillPolicy::Adaptive {
                min_chunk: 8,
                max_chunk: 128,
                decode_priority,
            }),
            Some(v) => {
                let chunk_len: usize = v.parse().map_err(|_| anyhow!(
                    "--prefill-chunk: want a token count or 'adaptive', got '{v}'"))?;
                if chunk_len == 0 {
                    bail!("--prefill-chunk must be > 0");
                }
                Ok(PrefillPolicy::Chunked { chunk_len, decode_priority })
            }
        },
        other => bail!("unknown prefill policy '{other}' (blocking|chunked)"),
    }
}

fn describe_policy(p: PrefillPolicy) -> String {
    match p {
        PrefillPolicy::Blocking => "blocking (whole-pool admission)".into(),
        PrefillPolicy::Chunked { chunk_len, decode_priority } => format!(
            "chunked ({chunk_len}-token chunks, {})",
            if decode_priority { "decode-priority" } else { "greedy" }),
        PrefillPolicy::Adaptive { min_chunk, max_chunk, decode_priority } => format!(
            "adaptive ({min_chunk}..{max_chunk}-token chunks sized from pool \
             pressure, {})",
            if decode_priority { "decode-priority" } else { "greedy" }),
    }
}

/// Paged-pool request from `--kv-pages` / `--page-len` (or the
/// paged-only `--kv-reserve` / `--kv-overcommit` knobs): `Some((pages,
/// page_len))` when the user asked for the paged layout. Geometry is
/// validated against the SIM pool shape (4 lanes × max_seq 320) only by
/// [`sim_paged_geometry`] — the pjrt backend takes its geometry from
/// the artifact manifest and uses the flags purely as a layout switch.
fn paged_request(a: &Args, reserve: ReservationPolicy, overcommit: f64,
                 kv_quant: PageCodec)
    -> Result<Option<(u64, u64)>>
{
    // lazy reservation / a real overcommit / a quantized codec only
    // exist on the paged layout, so they imply it; spelling out the
    // DEFAULTS (`--kv-reserve upfront`, `--kv-overcommit 1`,
    // `--kv-quant fp16`) must not switch the layout
    let implied = reserve == ReservationPolicy::Lazy || overcommit > 1.0
        || kv_quant != PageCodec::Fp16;
    if !a.has("kv-pages") && !a.has("page-len") && !implied {
        return Ok(None);
    }
    Ok(Some((a.get_u64("kv-pages", 0)?, a.get_u64("page-len", 64)?)))
}

/// Parse `--kv-reserve` (default: the PR 3 up-front reservation).
fn kv_reserve(a: &Args) -> Result<ReservationPolicy> {
    match a.get_str("kv-reserve", "upfront").as_str() {
        "upfront" => Ok(ReservationPolicy::Upfront),
        "lazy" => Ok(ReservationPolicy::Lazy),
        other => bail!("unknown reservation policy '{other}' (upfront|lazy)"),
    }
}

/// Resolve the mock/modeled paged geometry (their pools are hardcoded
/// at 4 lanes × max_seq 320): `--page-len` must tile max_seq, and
/// `--kv-pages 0`/absent defaults to the dense pool's memory budget
/// shrunk by `--kv-overcommit` — and re-tiled for `--kv-quant`: the
/// same page-buffer bytes hold 2x the pages under int8 (an explicit
/// `--kv-pages` wins verbatim).
fn sim_paged_geometry(pages: u64, page_len: u64, overcommit: f64,
                      kv_quant: PageCodec)
    -> Result<(usize, usize)>
{
    const SIM_MAX_SEQ: u64 = 320;
    const SIM_LANES: u64 = 4;
    if page_len == 0 || SIM_MAX_SEQ % page_len != 0 {
        bail!("--page-len must divide the sim pool's max_seq {SIM_MAX_SEQ}");
    }
    if !(1.0..=64.0).contains(&overcommit) {
        bail!("--kv-overcommit must be in [1, 64]");
    }
    let pages = if pages == 0 {
        let dense = SIM_LANES * SIM_MAX_SEQ / page_len;
        let codec_factor =
            PageCodec::Fp16.bytes_per_elem() / kv_quant.bytes_per_elem();
        (((dense as f64 * codec_factor) / overcommit).ceil() as u64).max(1)
    } else {
        pages
    };
    Ok((pages as usize, page_len as usize))
}

fn serve(a: &Args) -> Result<()> {
    let n = a.get_u64("requests", 8)? as usize;
    let new_tokens = a.get_u64("new-tokens", 32)? as usize;
    let spread = a.get_u64("spread", 1)? as usize;
    let stream = a.has("stream");
    let policy = prefill_policy(a)?;
    let reserve = kv_reserve(a)?;
    let overcommit = a.get_f64("kv-overcommit", 1.0)?;
    let kv_quant = PageCodec::parse(&a.get_str("kv-quant", "fp16"))?;
    let paged = paged_request(a, reserve, overcommit, kv_quant)?;
    // --shard-roles overrides --shards: the role list IS the topology
    let topo = match a.get("shard-roles") {
        Some(spec) => TopologyConfig::parse(spec)?,
        None => TopologyConfig::unified(a.get_u64("shards", 1)?.max(1) as usize),
    };
    let shards = topo.shards();
    let roles = topo.roles.clone();
    if topo.disaggregated_any() && paged.is_none() {
        bail!("--shard-roles needs the paged layout (add --kv-pages/--page-len): \
               migration moves page tables");
    }
    let prefix_share = a.has("prefix-share");
    let shared_prefix_len = a.get_u64("shared-prefix-len", 0)? as usize;
    if prefix_share && paged.is_none() {
        bail!("--prefix-share needs the paged layout (add --kv-pages/--page-len)");
    }
    let stop: Vec<i32> = match a.get("stop-token") {
        Some(v) => vec![v.parse().map_err(|_| anyhow!("--stop-token: bad token '{v}'"))?],
        None => Vec::new(),
    };
    // the SLO class every synthetic request is stamped with, and the
    // front door: either knob arms it; absent both, PR 9 bit-for-bit
    let slo = match SloClass::parse(&a.get_str("slo", "batch"))? {
        SloClass::Interactive => Slo::interactive(),
        SloClass::Batch => Slo::batch(),
    };
    let fd = if a.has("shed-watermark") || a.has("steal") {
        FrontDoorConfig::on()
            .with_shed_watermark(a.get_f64(
                "shed-watermark", FrontDoorConfig::default().shed_watermark)?)
            .with_steal(a.has("steal"))
    } else {
        FrontDoorConfig::default()
    };
    fd.validate()?;
    if fd.steal && shards == 1 {
        bail!("--steal needs --shards > 1: there is no second queue to steal from");
    }
    if fd.enabled {
        println!("front door: watermark {:.2}x pool, steal {}",
                 fd.shed_watermark, if fd.steal { "on" } else { "off" });
    }
    match a.get_str("backend", "pjrt").as_str() {
        "pjrt" => serve_pjrt(a, n, new_tokens, spread, stream, stop, policy,
                             paged.is_some(), reserve, roles, prefix_share,
                             kv_quant, slo, fd),
        "mock" => {
            let mut engines: Vec<Engine<MockBackend>> = match paged {
                Some((pages, page_len)) => {
                    let (pages, page_len) =
                        sim_paged_geometry(pages, page_len, overcommit, kv_quant)?;
                    split_budget(pages, shards)?
                        .into_iter()
                        .enumerate()
                        .map(|(i, p)| {
                            let mut backend =
                                MockBackend::paged(p, 128, 320, 512, page_len, p)
                                    .with_kv_quant(kv_quant);
                            if reserve == ReservationPolicy::Lazy {
                                // lazy growth legitimately extends tables
                                backend = backend.with_table_growth();
                            }
                            Engine::with_reservation(backend, policy, KvLayout::Paged,
                                                     reserve)
                                .with_shard_id(i)
                                .with_role(roles[i])
                                .with_prefix_share(prefix_share)
                        })
                        .collect()
                }
                None => split_budget(4, shards)?
                    .into_iter()
                    .enumerate()
                    .map(|(i, lanes)| {
                        Engine::with_policy(MockBackend::new(lanes, 128, 320, 512),
                                            policy)
                            .with_shard_id(i)
                    })
                    .collect(),
            };
            println!("prefill policy: {}", describe_policy(engines[0].policy()));
            let results = if shards > 1 {
                println!("engine shards: {shards} (free-page balanced)");
                drive_sim_sharded(&mut engines, n, new_tokens, spread, stream, &stop,
                                  shared_prefix_len, slo, fd)?
            } else {
                drive_sim(&mut engines[0], n, new_tokens, spread, stream, &stop,
                          shared_prefix_len, slo, fd)?
            };
            let per: Vec<ServeMetrics> =
                engines.iter().map(|e| e.metrics.clone()).collect();
            let merged = ServeMetrics::merge(&per);
            print_summary(&results, &merged, engines[0].lanes());
            print_shard_lines(&per);
            Ok(())
        }
        "modeled" => {
            let mut engines: Vec<Engine<ModeledBackend>> = match paged {
                Some((pages, page_len)) => {
                    let (pages, page_len) =
                        sim_paged_geometry(pages, page_len, overcommit, kv_quant)?;
                    split_budget(pages, shards)?
                        .into_iter()
                        .enumerate()
                        .map(|(i, p)| {
                            let mut backend = ModeledBackend::u280_paged(
                                p, 128, 320, 512, page_len, p, 4)
                                .with_kv_quant(kv_quant)
                                .with_role(roles[i]);
                            if reserve == ReservationPolicy::Lazy {
                                backend = backend.with_table_growth();
                            }
                            Engine::with_reservation(backend, policy, KvLayout::Paged,
                                                     reserve)
                                .with_shard_id(i)
                                .with_role(roles[i])
                                .with_prefix_share(prefix_share)
                        })
                        .collect()
                }
                None => split_budget(4, shards)?
                    .into_iter()
                    .enumerate()
                    .map(|(i, lanes)| {
                        Engine::with_policy(
                            ModeledBackend::u280(lanes, 128, 320, 512), policy)
                            .with_shard_id(i)
                    })
                    .collect(),
            };
            println!("prefill policy: {}", describe_policy(engines[0].policy()));
            let results = if shards > 1 {
                println!("engine shards: {shards} (free-page balanced, modeled \
                          clocks independent per shard)");
                drive_sim_sharded(&mut engines, n, new_tokens, spread, stream, &stop,
                                  shared_prefix_len, slo, fd)?
            } else {
                drive_sim(&mut engines[0], n, new_tokens, spread, stream, &stop,
                          shared_prefix_len, slo, fd)?
            };
            let per: Vec<ServeMetrics> =
                engines.iter().map(|e| e.metrics.clone()).collect();
            let merged = ServeMetrics::merge(&per);
            print_summary(&results, &merged, engines[0].lanes());
            print_shard_lines(&per);
            // aggregate modeled time: the slowest shard bounds the run
            let model_s = engines
                .iter()
                .map(|e| e.backend.model_time_s)
                .fold(0.0f64, f64::max);
            let toks: usize = results.iter().map(|r| r.tokens.len()).sum();
            println!("  modeled U280 time: {}  ({:.1} tok/s aggregate on {} \
                      replicated stage-engine pair{})",
                     fmt_secs(model_s), toks as f64 / model_s.max(1e-12),
                     shards, if shards == 1 { "" } else { "s" });
            for e in &engines {
                println!("    shard {}: prefill engine {}  decode engine {}",
                         e.shard_id(), fmt_secs(e.backend.prefill_clock_s),
                         fmt_secs(e.backend.decode_clock_s));
            }
            Ok(())
        }
        other => bail!("unknown backend '{other}' (pjrt|mock|modeled)"),
    }
}

/// Synthetic prompt for request `i`: deterministic per request, with an
/// optional `shared`-token head common to EVERY request — the
/// `--shared-prefix-len` "system prompt" the prefix cache feeds on.
fn sim_prompt(i: usize, s: usize, shared: usize) -> Vec<i32> {
    (0..s)
        .map(|j| {
            if j < shared {
                ((j * 13) % 512) as i32
            } else {
                ((i * 7 + j * 13) % 512) as i32
            }
        })
        .collect()
}

/// Submit a synthetic workload and run the step loop inline (no engine
/// thread needed for the artifact-free backends).
#[allow(clippy::too_many_arguments)]
fn drive_sim<B: ExecBackend>(engine: &mut Engine<B>, n: usize, new_tokens: usize,
                             spread: usize, stream: bool, stop: &[i32],
                             shared: usize, slo: Slo, fd: FrontDoorConfig)
    -> Result<Vec<GenResult>>
{
    let s = engine.prefill_len();
    if shared > s {
        bail!("--shared-prefix-len {shared} exceeds the {s}-token sim prompt");
    }
    let empty: VecDeque<GenRequest> = VecDeque::new();
    let mut shed = 0usize;
    for i in 0..n {
        let req = GenRequest::new(i as u64, sim_prompt(i, s, shared),
                                  skewed_budget(i, new_tokens, spread))
            .with_stop_tokens(stop.to_vec())
            .with_slo(slo);
        // front door: Batch arrivals past the watermark are refused at
        // the door instead of parking in an unbounded admission queue
        if fd.shed(&req.slo, cli_pool_snapshot(
                std::slice::from_ref(engine), &empty)).is_some() {
            shed += 1;
            continue;
        }
        engine.submit(req)?;
    }
    if fd.enabled {
        println!("  front door: {shed} shed (of {n} arrivals)");
    }
    let mut done = Vec::new();
    while engine.has_work() {
        let report = engine.step()?;
        if stream {
            for ev in &report.events {
                println!("  [req {}] #{} tok {}{}", ev.id, ev.index, ev.token,
                         if ev.done { "  <done>" } else { "" });
            }
        }
        done.extend(report.completed);
    }
    done.sort_by_key(|(seq, _)| *seq);
    Ok(done.into_iter().map(|(_, r)| r).collect())
}

/// Pool-wide congestion snapshot for the inline drivers' shed decision
/// (the openloop harness's arithmetic, generic over the backend): pages
/// in use plus queued demand over admitting shards, plus the
/// reservation demand already parked in the shared overflow FIFO — the
/// same quantities the threaded Router sums from shard load reports.
fn cli_pool_snapshot<B: ExecBackend>(engines: &[Engine<B>],
                                     overflow: &VecDeque<GenRequest>)
    -> PoolSnapshot
{
    let mut total = 0usize;
    let mut queued = 0usize;
    let mut gauge: Option<&Engine<B>> = None;
    for e in engines {
        if !e.role().accepts_new_requests() {
            continue;
        }
        let t = e.scheduler.total_pages();
        total += t;
        // in-use plus queued demand, NOT saturating free-page math: a
        // backlog deeper than one pool turn must keep registering for
        // a >1.0 watermark to mean "tolerate this much queueing"
        queued += t.saturating_sub(e.scheduler.free_pages())
            + e.scheduler.queued_pages();
        gauge.get_or_insert(e);
    }
    if total == 0 {
        // dense layout: no page pool to watermark, so never shed
        return PoolSnapshot { total_pages: 0, queued_pages: 0 };
    }
    let parked: usize = gauge
        .map(|e| overflow.iter().map(|r| e.scheduler.reservation_pages(r)).sum())
        .unwrap_or(0);
    PoolSnapshot { total_pages: total, queued_pages: queued + parked }
}

/// Drive N in-process engine shards to completion: requests flow
/// head-first through the least-loaded-by-free-pages placement with a
/// FIFO overflow (exactly the threaded Router's policy, inline), and
/// every busy shard steps once per round. With the front door on,
/// Batch arrivals past the watermark are shed at the door, Interactive
/// arrivals jump queued Batch, and hungry shards steal queued work.
/// Results in submission order.
#[allow(clippy::too_many_arguments)]
fn drive_sim_sharded<B: ExecBackend>(engines: &mut [Engine<B>], n: usize,
                                     new_tokens: usize, spread: usize, stream: bool,
                                     stop: &[i32], shared: usize, slo: Slo,
                                     fd: FrontDoorConfig)
    -> Result<Vec<GenResult>>
{
    let s = engines[0].prefill_len();
    if shared > s {
        bail!("--shared-prefix-len {shared} exceeds the {s}-token sim prompt");
    }
    let mut overflow: VecDeque<GenRequest> = VecDeque::new();
    let mut shed = 0usize;
    let mut stolen = 0usize;
    for i in 0..n {
        let req = GenRequest::new(i as u64, sim_prompt(i, s, shared),
                                  skewed_budget(i, new_tokens, spread))
            .with_stop_tokens(stop.to_vec())
            .with_slo(slo);
        // front door: Batch arrivals past the pool-wide watermark are
        // refused at the door; admitted Interactive goes ahead of
        // every queued Batch entry
        if fd.shed(&req.slo, cli_pool_snapshot(engines, &overflow)).is_some() {
            shed += 1;
            continue;
        }
        overflow_insert(fd.enabled, &mut overflow, req, |r| r.slo.class);
    }
    // sharing on → prefer the shard whose index holds the prompt's head
    let place: fn(&[Engine<B>], &GenRequest) -> Option<usize> =
        if engines[0].prefix_share() { place_shard_affine } else { place_shard };
    let mut done: Vec<GenResult> = Vec::new();
    let mut migrating: VecDeque<MigratedLane> = VecDeque::new();
    loop {
        // place the FIFO head while some shard has pages for it
        while let Some(head) = overflow.front() {
            let Some(sh) = place(engines, head) else { break };
            let req = overflow.pop_front().expect("front checked above");
            engines[sh].submit(req)?;
        }
        // front door: a hungry admitting shard (a free lane, nothing of
        // its own queued) pulls the youngest never-prefilled request
        // off the deepest per-shard queue — but only once the shared
        // FIFO is empty and nothing is mid-migration: parked work
        // always drains first, exactly as the threaded Router gates it
        if fd.enabled && fd.steal && overflow.is_empty() && migrating.is_empty() {
            let hungry = engines.iter().position(|e| {
                e.role().accepts_new_requests()
                    && e.scheduler.active() < e.scheduler.lanes()
                    && e.scheduler.queued() == 0
            });
            if let Some(hungry) = hungry {
                let counts: Vec<usize> = engines
                    .iter()
                    .enumerate()
                    .map(|(i, e)| {
                        if i == hungry { 0 } else { e.scheduler.stealable_queued() }
                    })
                    .collect();
                if let Some(donor) = pick_donor(&counts) {
                    if let Some((_, req)) =
                        engines[donor].scheduler.steal_youngest_queued()
                    {
                        engines[hungry].submit(req)?;
                        stolen += 1;
                    }
                }
            }
        }
        if engines.iter().all(|e| !e.has_work()) {
            if !migrating.is_empty() {
                return Err(anyhow!(
                    "migration stuck: no decode shard can fit a migrated page \
                     table (add pages or decode shards)"));
            }
            if overflow.is_empty() {
                break;
            }
            return Err(anyhow!(
                "placement stuck: a request's reservation exceeds every shard's \
                 pool (add pages or lower --kv-overcommit / --shards)"));
        }
        for sh in 0..engines.len() {
            if !engines[sh].has_work() {
                continue;
            }
            let report = engines[sh].step()?;
            if stream {
                for ev in &report.events {
                    println!("  [req {} shard {sh}] #{} tok {}{}", ev.id, ev.index,
                             ev.token, if ev.done { "  <done>" } else { "" });
                }
            }
            done.extend(report.completed.into_iter().map(|(_, r)| r));
            if engines[sh].role() == ShardRole::Prefill {
                migrating.extend(engines[sh].take_migratable());
            }
        }
        // re-home finished prefills onto the freest decode shard, head-first
        while let Some(head) = migrating.front() {
            let Some(dst) = place_migration(engines, head) else { break };
            let m = migrating.pop_front().expect("front checked above");
            engines[dst].import_migrated(m)?;
        }
    }
    if fd.enabled {
        println!("  front door: {shed} shed  {stolen} stolen  (of {n} arrivals)");
    }
    done.sort_by_key(|r| r.id);
    Ok(done)
}

fn print_shard_lines(per: &[ServeMetrics]) {
    if per.len() <= 1 {
        return;
    }
    for (i, m) in per.iter().enumerate() {
        let mig = if m.migrations_out + m.migrations_in > 0 {
            format!("  migrations out {} in {}", m.migrations_out, m.migrations_in)
        } else {
            String::new()
        };
        println!("  shard {i}: {} requests  peak concurrency {}  pages peak {}/{}  \
                  grown {}  preemptions {}{mig}",
                 m.requests, m.peak_active, m.kv_pages_peak, m.kv_pages_total,
                 m.kv_pages_grown, m.preemptions);
    }
}

#[allow(clippy::too_many_arguments)]
fn serve_pjrt(a: &Args, n: usize, new_tokens: usize, spread: usize, stream: bool,
              stop: Vec<i32>, policy: PrefillPolicy, paged: bool,
              reserve: ReservationPolicy, roles: Vec<ShardRole>, prefix_share: bool,
              kv_quant: PageCodec, slo: Slo, fd: FrontDoorConfig)
    -> Result<()>
{
    let shards = roles.len();
    let artifacts = a.get_str("artifacts", "artifacts");
    println!("prefill policy requested: {}", describe_policy(policy));
    let layout = if paged {
        // geometry is baked into the artifacts; the flags only pick the
        // layout here
        println!("kv layout requested: paged (geometry from the manifest)");
        KvLayout::Paged
    } else {
        KvLayout::Dense
    };
    let arrival_rate: Option<f64> = match a.get("arrival-rate") {
        Some(v) => Some(v.parse().map_err(|_| anyhow!("--arrival-rate: bad rate '{v}'"))?),
        None => None,
    };
    let rt = Runtime::open(&artifacts)?;
    let s = rt.manifest.serving.prefill_len;
    let lanes = rt.manifest.serving.batch;
    let bytes = std::fs::read(rt.dir().join("prompt_tokens.bin"))?;
    let toks: Vec<i32> = bytes
        .chunks_exact(4)
        .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect();
    let base: Vec<Vec<i32>> = toks.chunks_exact(s).map(|c| c.to_vec()).collect();
    drop(rt);

    if shards > 1 {
        println!("engine shards: {shards} (one artifact runtime per shard)");
    }
    // the whole knob ladder collapses into one validated config
    let cfg = ServeConfig::default()
        .policy(policy)
        .layout(layout)
        .reserve(reserve)
        .prefix_share(prefix_share)
        .kv_quant(kv_quant)
        .front_door(fd)
        .roles(roles);
    let router = RouterBuilder::from_config(cfg).spawn(artifacts.to_string())?;
    if stream {
        let events = router.subscribe()?;
        std::thread::spawn(move || {
            while let Ok(ev) = events.recv() {
                println!("  [req {}] #{} tok {}{}", ev.id, ev.index, ev.token,
                         if ev.done { "  <done>" } else { "" });
            }
        });
    }
    let queue: Vec<GenRequest> = (0..n)
        .map(|i| {
            GenRequest::new(i as u64, base[i % base.len()].clone(),
                            skewed_budget(i, new_tokens, spread))
                .with_stop_tokens(stop.clone())
                .with_slo(slo)
        })
        .collect();

    let t0 = std::time::Instant::now();
    match arrival_rate {
        // staggered arrivals: the engine steps between submissions and
        // backfills freed lanes with the newly arrived requests
        Some(rate) if rate > 0.0 => {
            let gap = std::time::Duration::from_secs_f64(1.0 / rate);
            let total = queue.len();
            for (i, req) in queue.into_iter().enumerate() {
                router.submit(vec![req])?;
                if i + 1 < total {
                    std::thread::sleep(gap);
                }
            }
        }
        _ => router.submit(queue)?,
    }
    let results = router.drain()?;
    let wall = t0.elapsed();
    let m = router.metrics()?;
    print_summary(&results, &m, lanes);
    if shards > 1 {
        print_shard_lines(&router.shard_metrics()?);
    }
    println!("  wall time: {}", fmt_secs(wall.as_secs_f64()));
    for r in results.iter().take(2) {
        println!("  req {}: ttft {} first tokens {:?}",
                 r.id, fmt_secs(r.ttft.as_secs_f64()), &r.tokens[..r.tokens.len().min(8)]);
    }
    Ok(())
}

fn print_summary(results: &[GenResult], m: &ServeMetrics, lanes: usize) {
    use flexllm::coordinator::FinishReason;
    println!("served {} requests", results.len());
    println!("  prefill: {:.0} tok/s ({} calls)   decode: {:.1} tok/s ({} iterations)",
             m.prefill_tps(), m.prefill_calls, m.decode_tps(), m.iterations);
    println!("  ttft p50/p95: {} / {}   tpot p50/p95: {} / {}",
             fmt_secs(m.ttft_p50()), fmt_secs(m.ttft_p95()),
             fmt_secs(m.tpot_p50()), fmt_secs(m.tpot_p95()));
    println!("  ttft breakdown p95: queue {}  prefill {}{}",
             fmt_secs(m.queue_wait_p95()), fmt_secs(m.prefill_wait_p95()),
             if m.prefill_chunks > 0 {
                 format!("  ({} chunks fed)", m.prefill_chunks)
             } else {
                 String::new()
             });
    println!("  lane utilization: {:.1}%  ({} lane-steps over {} invocations × {} \
              lanes, {} scheduler ticks)",
             m.lane_utilization(lanes) * 100.0, m.lane_steps, m.decode_invocations,
             lanes, m.iterations);
    if m.kv_pages_total > 0 {
        println!("  kv pages: {}/{} peak  occupancy p50/p95: {:.0}%/{:.0}%  \
                  fragmentation p95: {:.0}%  peak concurrency: {}",
                 m.kv_pages_peak, m.kv_pages_total,
                 m.page_occupancy_p50() * 100.0, m.page_occupancy_p95() * 100.0,
                 m.page_frag_p95() * 100.0, m.peak_active);
        if m.kv_pages_grown > 0 || m.preemptions > 0 {
            println!("  lazy reservation: {} pages grown  {} preemptions  \
                      rows reserved/written peak: {}/{}",
                     m.kv_pages_grown, m.preemptions,
                     m.kv_rows_reserved_peak, m.kv_rows_written_peak);
        }
        if m.prefix_hits + m.prefix_misses > 0 {
            println!("  prefix share: hit rate {:.0}% ({} hits / {} misses)  \
                      pages shared {}  cow copies {}",
                     m.prefix_hit_rate() * 100.0, m.prefix_hits, m.prefix_misses,
                     m.kv_pages_shared, m.cow_copies);
        }
        if !m.kv_codec.is_empty() && m.kv_codec != "fp16" {
            println!("  kv codec: {} ({:.3} B/row-elem effective)  \
                      rows dequantized {}",
                     m.kv_codec, m.kv_bytes_per_row_effective, m.dequant_rows);
        }
    }
    let stopped = results.iter()
        .filter(|r| r.finish_reason == FinishReason::Stop)
        .count();
    if stopped > 0 {
        println!("  early stop: {stopped} requests hit a stop token");
    }
}

fn dse(device: &str, stage: &str, prefill: u64, decode: u64, max_shards: usize,
       rate: f64) -> Result<()> {
    let model = ModelDims::llama32_1b();
    let dev = device_of(device)?;
    if stage == "shard-mix" {
        use flexllm::coordinator::{ArrivalProcess, OpenLoopConfig, PagedPoolConfig};
        {
            // prefill-heavy: 128-token prompts against 16..48-token
            // budgets, Poisson arrivals, equal total KV memory per
            // topology (the pool splits across however many shards)
            let cfg = OpenLoopConfig {
                requests: 48,
                arrival: ArrivalProcess::Poisson { rate_rps: rate },
                min_new_tokens: 16,
                max_new_tokens: 48,
                paged: Some(PagedPoolConfig::same_memory_as_dense(4, 320, 32, 16)),
                ..OpenLoopConfig::default()
            };
            let r = flexllm::dse::tune_shard_mix(PrefillPolicy::chunked(32), &cfg,
                                                 max_shards)?;
            println!("shard-mix DSE (poisson {rate} req/s, prefill-heavy, equal \
                      total KV, up to {max_shards} shards):");
            for p in &r.points {
                println!("  {:<10} ttft p95 {:>10}  decode {:>8.1} tok/s  \
                          migrations {}",
                         p.summary, fmt_secs(p.ttft_p95_s), p.decode_tps,
                         p.migrations);
            }
            let (bm, bh) = (r.best_mixed(), r.best_homogeneous());
            println!("  best mixed:       {} (ttft p95 {}, {:.1} tok/s)",
                     bm.summary, fmt_secs(bm.ttft_p95_s), bm.decode_tps);
            println!("  best homogeneous: {} (ttft p95 {}, {:.1} tok/s)",
                     bh.summary, fmt_secs(bh.ttft_p95_s), bh.decode_tps);
            return Ok(());
        }
    }
    match stage {
        "prefill" => {
            let r = flexllm::dse::tune_prefill(&model, &dev, prefill);
            println!("prefill DSE on {}: best TP={} WPkqvo={} WPmha={} WPffn={} → {}",
                     dev.name, r.best.tp, r.best.wp_kqvo, r.best.wp_mha, r.best.wp_ffn,
                     fmt_secs(r.latency_s));
            println!("  evaluated {} candidates, {} feasible", r.evaluated, r.feasible);
            let arch = PrefillArch::new(r.best, model, dev);
            println!("  binding util {:.1}%  peak BW {:.0} GB/s",
                     arch.utilization().max_class() * 100.0,
                     arch.peak_bandwidth() / 1e9);
        }
        "decode" => {
            let r = flexllm::dse::tune_decode(&model, &dev, prefill, decode);
            println!("decode DSE on {}: best BP={} WPint4={} WPmha={} → {}",
                     dev.name, r.best.bp, r.best.wp_int4, r.best.wp_mha,
                     fmt_secs(r.latency_s));
            println!("  evaluated {} candidates, {} feasible", r.evaluated, r.feasible);
            let arch = DecodeArch::new(r.best, model, dev);
            println!("  binding util {:.1}%  peak BW {:.0} GB/s  partitions {}",
                     arch.utilization().max_class() * 100.0,
                     arch.peak_bandwidth() / 1e9, arch.partitions);
        }
        other => bail!("unknown stage '{other}' (prefill|decode)"),
    }
    Ok(())
}

fn simulate(device: &str, stage: &str, tokens: u64) -> Result<()> {
    let sys = match device {
        "u280" => AcceleratorSystem::u280(),
        "v80" => AcceleratorSystem::v80(),
        other => bail!("unknown device '{other}' (u280|v80)"),
    };
    match stage {
        "prefill" => {
            let r = sys.prefill.simulate(tokens);
            println!("prefill sim ({} tokens/layer): {:.0} cycles/layer, mean util {:.1}%",
                     tokens, r.makespan_cycles, r.mean_utilization * 100.0);
            println!("  analytic {}  simulated {}",
                     fmt_secs(sys.prefill.analytic_latency_s(tokens)),
                     fmt_secs(sys.prefill.simulated_latency_s(tokens)));
            for n in &r.nodes {
                println!("  {:<24} busy {:>12.0}  stall {:>12.0}  util {:>5.1}%",
                         n.name, n.busy_cycles, n.stall_cycles, n.utilization * 100.0);
            }
        }
        "decode" => {
            let r = sys.decode.simulate(1024, tokens);
            println!("decode sim ({} tokens): {:.0} cycles, mean util {:.1}%",
                     tokens, r.makespan_cycles, r.mean_utilization * 100.0);
            println!("  analytic {}  simulated {}",
                     fmt_secs(sys.decode.analytic_latency_s(1024, tokens)),
                     fmt_secs(sys.decode.simulated_latency_s(1024, tokens)));
        }
        other => bail!("unknown stage '{other}' (prefill|decode)"),
    }
    Ok(())
}

/// The `verify` gate: bounded exhaustive model check of the KV
/// page/refcount/migration machine plus the architectural source lint.
/// Prints one line per matrix cell, a full minimized counterexample for
/// every violation, and fails (nonzero exit) if anything fired.
fn verify(a: &Args) -> Result<()> {
    use flexllm::verify::{archlint, mc};
    let budget = mc::McBudget {
        branch_depth: a.get_u64("depth", 6)?.max(1) as usize,
        ..mc::McBudget::default()
    };
    let bounded = a.has("bounded");
    let arch = a.has("arch-lint");
    let replay = a.get("replay");
    // no mode flag → run everything (the CI default)
    let all = !bounded && !arch && replay.is_none();

    let mut counterexamples: Vec<mc::Counterexample> = Vec::new();
    let mut lint_violations = 0usize;

    if let Some(spec) = replay {
        let report = mc::replay(spec, &budget)?;
        match report.violation {
            Some(ce) => {
                println!("{ce}");
                counterexamples.push(ce);
            }
            None => println!("replay {spec}: clean ({} states visited)",
                             report.unique_states),
        }
    }
    if bounded || all {
        let reports = match a.get("config") {
            Some(name) => {
                let cfg = mc::config_by_name(name).ok_or_else(|| anyhow!(
                    "unknown config '{name}' — the matrix cells are named \
                     <upfront|lazy>-<share|noshare>-<unified|disagg>-<fp16|int8>"))?;
                vec![mc::check_config(&cfg, &budget)?]
            }
            None => mc::check_all(&budget)?,
        };
        let mut episodes = 0usize;
        let mut states = 0usize;
        for r in &reports {
            println!("  {:<30} {:>7} interleavings  {:>7} states  {}",
                     r.config, r.interleavings, r.unique_states,
                     if r.violation.is_some() { "VIOLATION" } else { "ok" });
            episodes += r.interleavings;
            states += r.unique_states;
            if let Some(ce) = &r.violation {
                println!("{ce}");
                counterexamples.push(ce.clone());
            }
        }
        println!("bounded model check: {} configs, {} interleavings, {} unique \
                  states at depth {}",
                 reports.len(), episodes, states, budget.branch_depth);
    }
    if arch || all {
        let root = archlint::default_src_root();
        let violations = archlint::lint(&root)?;
        for v in &violations {
            println!("  {v}");
        }
        lint_violations = violations.len();
        println!("arch lint over {}: {}", root.display(),
                 if lint_violations == 0 {
                     "clean".to_string()
                 } else {
                     format!("{lint_violations} violation(s)")
                 });
    }
    if let Some(path) = a.get("trace-out") {
        if counterexamples.is_empty() {
            // an empty artifact still tells CI the gate ran
            std::fs::write(path, "")?;
        } else {
            let specs: String = counterexamples
                .iter()
                .map(|ce| format!("{}\n", ce.replay_spec()))
                .collect();
            std::fs::write(path, specs)?;
            println!("wrote {} replay spec(s) to {path}", counterexamples.len());
        }
    }
    if !counterexamples.is_empty() || lint_violations > 0 {
        bail!("verify failed: {} counterexample(s), {} arch-lint violation(s)",
              counterexamples.len(), lint_violations);
    }
    println!("verify: all gates clean");
    Ok(())
}

/// Rough LoC counter for Table IV (this repo's own code sizes).
fn count_loc() -> (usize, usize) {
    fn count_dir(dir: &str, ext: &str) -> usize {
        let mut total = 0;
        let mut stack = vec![std::path::PathBuf::from(dir)];
        while let Some(d) = stack.pop() {
            let Ok(entries) = std::fs::read_dir(&d) else { continue };
            for e in entries.flatten() {
                let p = e.path();
                if p.is_dir() {
                    stack.push(p);
                } else if p.extension().map(|x| x == ext).unwrap_or(false) {
                    if let Ok(s) = std::fs::read_to_string(&p) {
                        total += s.lines().filter(|l| !l.trim().is_empty()).count();
                    }
                }
            }
        }
        total
    }
    (count_dir("python", "py"), count_dir("rust", "rs") + count_dir("examples", "rs"))
}
