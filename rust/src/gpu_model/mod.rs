//! A100 GPU roofline baselines (paper Sec. VI-A: HuggingFace BF16 under
//! vLLM, and INT4 GPTQ with Marlin kernels under vLLM).
//!
//! Substitution argument (DESIGN.md §2): the paper's GPU comparisons rest
//! on two measured facts — prefill is compute-bound at high utilization,
//! decode is bandwidth-bound at *low effective* bandwidth utilization
//! (13.06% average for A100+vLLM on this 1B model, Sec. VI-B1). We model
//! exactly those two regimes with the utilization constants the paper
//! reports, plus a per-step launch floor that dominates tiny models.

use crate::config::{DeviceConfig, ModelDims};

/// GPU weight/KV precision mode.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GpuMode {
    /// HuggingFace BF16 weights under vLLM.
    Bf16,
    /// INT4 GPTQ + Marlin kernels under vLLM.
    GptqMarlinInt4,
}

impl GpuMode {
    pub fn weight_bytes(self) -> f64 {
        match self {
            GpuMode::Bf16 => 2.0,
            GpuMode::GptqMarlinInt4 => 0.5,
        }
    }

    pub fn label(self) -> &'static str {
        match self {
            GpuMode::Bf16 => "A100 BF16 (vLLM)",
            GpuMode::GptqMarlinInt4 => "A100 INT4 GPTQ-Marlin (vLLM)",
        }
    }
}

/// Calibration constants (documented in DESIGN.md §2 / EXPERIMENTS.md).
mod cal {
    /// Prefill model-FLOPs utilization for a 1B model under vLLM.
    /// (Small models can't fill the A100; Fig. 2 shows ~45-55% compute
    /// utilization during prefill.)
    pub const PREFILL_MFU: f64 = 0.48;
    /// Effective HBM bandwidth utilization during single-stream decode —
    /// the paper measures 13.06% average for A100+vLLM.
    pub const DECODE_BW_UTIL: f64 = 0.1306;
    /// Marlin's fused dequant kernels sustain somewhat better effective
    /// bandwidth on the weight stream.
    pub const MARLIN_BW_UTIL: f64 = 0.16;
    /// Per-decode-step launch/sync floor (CUDA graphs reduce but don't
    /// eliminate it for a 16-layer model).
    pub const STEP_FLOOR_S: f64 = 3.5e-4;
    /// Average device power during prefill/decode (W) — A100 boards run
    /// well below TDP on memory-bound decode.
    pub const PREFILL_POWER_W: f64 = 265.0;
    pub const DECODE_POWER_W: f64 = 165.0;
}

/// An A100 running the target model in a given mode.
#[derive(Debug)]
pub struct GpuBaseline {
    pub device: DeviceConfig,
    pub model: ModelDims,
    pub mode: GpuMode,
}

impl GpuBaseline {
    pub fn a100(model: ModelDims, mode: GpuMode) -> Self {
        GpuBaseline { device: DeviceConfig::a100(), model, mode }
    }

    /// Prefill latency: compute-bound at PREFILL_MFU (plus attention
    /// FLOPs, which matter at long context).
    pub fn prefill_latency_s(&self, l_p: u64) -> f64 {
        let dense = self.model.flops_per_token() * l_p as f64;
        let attn = 2.0 * (self.model.n_layers * self.model.d_model) as f64
            * (l_p as f64).powi(2);
        (dense + attn) / (self.device.peak_tflops * 1e12 * cal::PREFILL_MFU)
    }

    /// Decode latency: bandwidth-bound on weights + KV traffic at the
    /// measured effective utilization, floored by launch overhead.
    pub fn decode_latency_s(&self, l_p: u64, l_d: u64) -> f64 {
        let avg_ctx = l_p + l_d / 2;
        let weights = self.model.decode_weight_bytes(self.mode.weight_bytes(),
                                                     self.mode.weight_bytes());
        let kv = self.model.kv_bytes_per_token(avg_ctx, 2.0); // BF16 KV under vLLM
        let util = match self.mode {
            GpuMode::Bf16 => cal::DECODE_BW_UTIL,
            GpuMode::GptqMarlinInt4 => cal::MARLIN_BW_UTIL,
        };
        let per_token = ((weights + kv) / (self.device.hbm_bw * util))
            .max(cal::STEP_FLOOR_S);
        l_d as f64 * per_token
    }

    pub fn e2e_latency_s(&self, l_p: u64, l_d: u64) -> f64 {
        self.prefill_latency_s(l_p) + self.decode_latency_s(l_p, l_d)
    }

    pub fn decode_throughput(&self, l_p: u64, l_d: u64) -> f64 {
        l_d as f64 / self.decode_latency_s(l_p, l_d)
    }

    /// Tokens per joule over the full request.
    pub fn tokens_per_joule(&self, l_p: u64, l_d: u64) -> f64 {
        let e = self.prefill_latency_s(l_p) * cal::PREFILL_POWER_W
            + self.decode_latency_s(l_p, l_d) * cal::DECODE_POWER_W;
        l_d as f64 / e
    }

    /// Fig. 2: (compute utilization, bandwidth utilization) per stage.
    pub fn fig2_utilization(&self, l_p: u64, l_d: u64) -> Fig2 {
        let pre_t = self.prefill_latency_s(l_p);
        let pre_flops = self.model.flops_per_token() * l_p as f64
            + 2.0 * (self.model.n_layers * self.model.d_model) as f64 * (l_p as f64).powi(2);
        let pre_compute = pre_flops / pre_t / (self.device.peak_tflops * 1e12);
        // prefill reads weights once + writes KV
        let pre_bytes = self.model.n_params() as f64 * self.mode.weight_bytes()
            + self.model.kv_bytes_per_token(1, 2.0) * l_p as f64;
        let pre_bw = pre_bytes / pre_t / self.device.hbm_bw;

        let dec_t = self.decode_latency_s(l_p, l_d);
        let dec_flops = self.model.flops_per_token() * l_d as f64;
        let dec_compute = dec_flops / dec_t / (self.device.peak_tflops * 1e12);
        let dec_bytes = (self.model.decode_weight_bytes(self.mode.weight_bytes(),
                                                        self.mode.weight_bytes())
            + self.model.kv_bytes_per_token(l_p + l_d / 2, 2.0))
            * l_d as f64;
        let dec_bw = dec_bytes / dec_t / self.device.hbm_bw;
        Fig2 { prefill_compute: pre_compute, prefill_bw: pre_bw,
               decode_compute: dec_compute, decode_bw: dec_bw }
    }
}

/// Fig. 2 datapoint: stage utilization of compute and memory bandwidth.
#[derive(Debug, Clone, Copy)]
pub struct Fig2 {
    pub prefill_compute: f64,
    pub prefill_bw: f64,
    pub decode_compute: f64,
    pub decode_bw: f64,
}

#[cfg(test)]
mod tests {
    use super::*;

    fn bf16() -> GpuBaseline {
        GpuBaseline::a100(ModelDims::llama32_1b(), GpuMode::Bf16)
    }

    #[test]
    fn prefill_is_fast_decode_is_slow() {
        // the stage divergence that motivates the paper (Fig. 2)
        let g = bf16();
        let pre = g.prefill_latency_s(1024);
        let dec = g.decode_latency_s(1024, 1024);
        assert!(pre < 0.2, "prefill = {pre}");
        assert!(dec > 5.0, "decode = {dec}");
    }

    #[test]
    fn fig2_stage_divergence() {
        let g = bf16();
        let f = g.fig2_utilization(1024, 1024);
        // prefill: compute-dominated; decode: bandwidth-dominated
        assert!(f.prefill_compute > 0.3 && f.prefill_compute <= 1.0);
        assert!(f.decode_compute < 0.05, "decode compute = {}", f.decode_compute);
        assert!(f.decode_bw > 0.08 && f.decode_bw < 0.3);
        assert!(f.decode_bw > f.decode_compute * 3.0);
    }

    #[test]
    fn marlin_faster_than_bf16_decode() {
        let b = bf16();
        let m = GpuBaseline::a100(ModelDims::llama32_1b(), GpuMode::GptqMarlinInt4);
        assert!(m.decode_latency_s(1024, 1024) < b.decode_latency_s(1024, 1024) / 2.0);
    }

    #[test]
    fn paper_headline_u280_ratios() {
        // Fig. 7 headline: U280 ≈ 1.29× E2E, 1.64× decode tput, 3.14×
        // tokens/J over A100 BF16 (averaged over the workload grid).
        use crate::arch::AcceleratorSystem;
        let gpu = bf16();
        let fpga = AcceleratorSystem::u280();
        let grid = [(512u64, 256u64), (512, 512), (512, 1024), (512, 2048),
                    (1024, 256), (1024, 512), (1024, 1024), (1024, 2048)];
        let mut e2e = 0.0;
        let mut tput = 0.0;
        let mut energy = 0.0;
        for (lp, ld) in grid {
            e2e += gpu.e2e_latency_s(lp, ld) / fpga.e2e_latency_s(lp, ld);
            tput += fpga.decode_throughput(lp, ld) / gpu.decode_throughput(lp, ld);
            energy += fpga.tokens_per_joule(lp, ld) / gpu.tokens_per_joule(lp, ld);
        }
        let n = grid.len() as f64;
        let (e2e, tput, energy) = (e2e / n, tput / n, energy / n);
        // who-wins and rough factors must match the paper
        assert!(e2e > 1.0 && e2e < 1.8, "E2E speedup = {e2e} (paper 1.29)");
        assert!(tput > 1.2 && tput < 2.2, "decode tput ratio = {tput} (paper 1.64)");
        assert!(energy > 2.2 && energy < 4.5, "tokens/J ratio = {energy} (paper 3.14)");
    }
}
