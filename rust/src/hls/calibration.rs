//! Calibration constants for the resource / timing models.
//!
//! The paper reports *measured* post-P&R utilization for its U280/V80
//! designs (Table VI). We cannot run Vivado, so the per-PE and per-lane
//! fabric costs below are fitted so the paper's exact architecture
//! configurations land near the reported utilization rows (the
//! calibration test in `arch::tests` asserts the fit). They are kept in
//! one place so the fit is auditable and re-tunable.

use crate::config::Precision;
use crate::hls::Resources;

/// Fabric cost of one multiply-accumulate PE at a given precision.
///
/// INT4 MACs map to LUT fabric (two per LUT6-pair cluster) with a small
/// amortized DSP share from the reduction tree; INT8 packs two MACs per
/// DSP48/DSP58; FP16/FP32 consume whole DSP cascades.
pub fn pe_cost(p: Precision) -> Resources {
    match p {
        Precision::Int4 => Resources { lut: 68.0, ff: 52.0, dsp: 0.42, ..Resources::zero() },
        Precision::Int8 => Resources { lut: 34.0, ff: 44.0, dsp: 0.55, ..Resources::zero() },
        Precision::Fp16 => Resources { lut: 90.0, ff: 110.0, dsp: 1.0, ..Resources::zero() },
        Precision::Fp32 => Resources { lut: 180.0, ff: 220.0, dsp: 2.0, ..Resources::zero() },
    }
}

/// Lane-count scaling for multi-lane (TP/BP) elementwise modules: control
/// logic, LUTROM function tables and schedulers are shared across lanes,
/// so fabric grows sub-linearly. Fitted exponent 0.8 reconciles the U280
/// (BP=16) and V80 (BP=64) decode rows of Table VI with one coefficient
/// set.
pub fn lane_scale(lanes: u64) -> f64 {
    (lanes.max(1) as f64).powf(0.8)
}

/// Fabric cost of one non-linear lane (one token-lane of softmax / norm /
/// RoPE / Swish datapath): FP16 exp/div/sqrt pipelines are DSP-heavy.
pub fn nonlinear_lane_cost() -> Resources {
    Resources { lut: 3_100.0, ff: 3_400.0, dsp: 11.0, bram: 0.6, ..Resources::zero() }
}

/// One quantizer / dequantizer lane (comparators, round, clip, plus the
/// per-channel auxiliary-data buffers for the dequantizer).
pub fn quant_lane_cost(dynamic: bool) -> Resources {
    let base = Resources { lut: 900.0, ff: 1_050.0, dsp: 2.0, bram: 0.4, ..Resources::zero() };
    if dynamic {
        // dynamic adds the online min/max reduction tree
        base + Resources { lut: 450.0, ff: 380.0, dsp: 0.5, ..Resources::zero() }
    } else {
        base
    }
}

/// FHT butterfly lane (adders only — the paper's motivation for FHT over
/// explicit rotations).
pub fn fht_lane_cost(dim: u64) -> Resources {
    let stages = (dim as f64).log2().ceil();
    Resources {
        lut: 140.0 * stages,
        ff: 160.0 * stages,
        bram: 0.25 * stages,
        ..Resources::zero()
    }
}

/// Static platform infrastructure: HBM AXI adapters, host DMA, control.
/// (Vitis platform region on U280 occupies a comparable share.)
pub fn platform_overhead() -> Resources {
    Resources {
        lut: 118_000.0,
        ff: 180_000.0,
        dsp: 12.0,
        bram: 210.0,
        uram: 0.0,
        ..Resources::zero()
    }
}

/// On-chip buffering for a streamed weight channel of width `wp` at
/// precision `p` (double-buffered BRAM FIFO per channel).
pub fn weight_stream_buffers(wp: u64, p: Precision) -> Resources {
    Resources {
        bram: 0.09 * wp as f64 * p.bytes().max(0.5),
        lut: 14.0 * wp as f64,
        ff: 20.0 * wp as f64,
        ..Resources::zero()
    }
}

/// Activation / KV tile buffering in URAM for a module holding `bytes`
/// of working set on-chip (URAM = 288 Kb = 36 KiB per block).
pub fn uram_for_bytes(bytes: f64) -> Resources {
    Resources { uram: (bytes / 36_864.0).ceil(), ..Resources::zero() }
}

/// Measurement gap: the paper's on-board latencies exceed the closed-form
/// bounds (Eqs. 1–7). Prefill runs close to its bound (streaming hides
/// most stalls: 1.65 s measured vs ~1.48 s Eq. 4 on U280 → ×1.12).
/// Decode pays dependency stalls, HBM bank conflicts on KV fetch and
/// per-token control overhead that the bound ignores (6.94 s measured vs
/// ~4.7 s Eq. 6 → ×1.45; the same factor lands the V80 estimate at the
/// paper's 1.68 s). Both factors are fitted once against Table VI and
/// applied uniformly — never per-experiment.
pub const MEASURED_OVERHEAD_PREFILL: f64 = 1.12;
pub const MEASURED_OVERHEAD_DECODE: f64 = 1.45;
