//! The FlexLLM HLS module library **simulator** — the FPGA substrate.
//!
//! The paper's artifact is a TAPA C++ template library plus bitstreams;
//! neither Vivado nor an Alveo card is available here, so this module
//! implements the library's *semantics*: every template in Table III with
//! its parallelism knobs, and for each composed design the cycle count
//! (Eqs. 1–7), the fabric resources, the HBM traffic, and the dataflow
//! pipeline behaviour (Fig. 1). See DESIGN.md §2 for the substitution
//! argument.

pub mod calibration;
pub mod dataflow;
pub mod floorplan;
pub mod module;
pub mod pipeline_sim;
pub mod resource;
pub mod stream;

pub use dataflow::{DataflowGraph, Node, NodeId};
pub use floorplan::{achieved_frequency, partition_for_frequency};
pub use module::{
    Dequantizer, DecodeLinear, FhtModule, KvCache, MhaEngine, ModuleKind, ModuleRef,
    ModuleTemplate, NonLinear, NonLinearKind, PrefillLinear, Quantizer, Sampling,
};
pub use pipeline_sim::{simulate, simulate_recurrent, Dependency, NodeStats, SimResult};
pub use resource::Resources;
pub use stream::StreamEdge;
