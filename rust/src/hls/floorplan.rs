//! Floorplan / achieved-frequency model (the AutoBridge stand-in).
//!
//! The paper closes timing with AutoBridge-style coarse floorplanning and
//! reports 304 / 292 / 290 MHz for the U280 prefill / decode / HMT
//! designs against a ~320 MHz HLS target, and estimates 300 MHz on V80.
//! We model the two effects that dominate achieved frequency on multi-die
//! Alveo parts:
//!
//! * **congestion derating** — routing delay grows once utilization
//!   crosses ~55% of the binding resource class;
//! * **fan-out derating** — very wide engines (the decode WP=1024 linear)
//!   lose frequency to high-fanout nets unless partitioned into identical
//!   submodules (the paper's mitigation, Sec. IV-B).

use crate::config::{DeviceConfig, DeviceKind};

/// Achieved post-P&R clock for a composed design.
///
/// * `util` — binding (max-class) resource utilization in 0..1;
/// * `widest_engine` — WP of the widest single engine after partitioning
///   (`wp / partitions`).
pub fn achieved_frequency(dev: &DeviceConfig, util: f64, widest_engine: u64) -> f64 {
    match dev.kind {
        DeviceKind::A100 => 1.41e9, // GPU boost clock; unused by FPGA paths
        DeviceKind::U280 | DeviceKind::V80 => {
            let congestion = 0.12 * ((util - 0.45).max(0.0) / 0.45).powf(1.5);
            let fanout = 0.035 * ((widest_engine as f64 / 256.0).log2().max(0.0));
            let derate = 1.0 - congestion.min(0.30) - fanout.min(0.15);
            dev.target_clock_hz * derate.max(0.5)
        }
    }
}

/// Choose the partition count for a wide decode engine: the smallest
/// split whose submodule width no longer costs more than ~2% frequency.
pub fn partition_for_frequency(wp: u64) -> u64 {
    let mut parts = 1;
    while wp / parts > 512 && parts < 32 {
        parts *= 2;
    }
    parts
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::DeviceConfig;

    #[test]
    fn matches_paper_u280_prefill_band() {
        // Table VI: prefill util max 66% (CLB), widest engine WP_ffn=96
        let f = achieved_frequency(&DeviceConfig::u280(), 0.66, 96);
        assert!(f > 295e6 && f < 315e6, "f = {}", f / 1e6);
    }

    #[test]
    fn matches_paper_u280_decode_band() {
        // Table VI: decode util max 76% (CLB), WP_int4=1024 partitioned ×4
        let parts = partition_for_frequency(1024);
        let f = achieved_frequency(&DeviceConfig::u280(), 0.76, 1024 / parts);
        assert!(f > 280e6 && f < 300e6, "f = {}", f / 1e6);
    }

    #[test]
    fn frequency_decreases_with_congestion() {
        let d = DeviceConfig::u280();
        assert!(achieved_frequency(&d, 0.9, 64) < achieved_frequency(&d, 0.6, 64));
    }

    #[test]
    fn partitioning_recovers_frequency() {
        let d = DeviceConfig::u280();
        let whole = achieved_frequency(&d, 0.7, 4096);
        let split = achieved_frequency(&d, 0.7, 4096 / partition_for_frequency(4096));
        assert!(split > whole);
    }
}
