//! Composition graph: the `tapa::task().invoke(...)` analog.
//!
//! A [`DataflowGraph`] holds module *instances* (nodes) connected by
//! FIFO streams (edges). Two composition styles, mirroring Fig. 4:
//!
//! * **spatial** — distinct instances connected by streams run
//!   concurrently, pipelined at token granularity;
//! * **temporal reuse** — one instance serves several logical roles
//!   sequentially; model it by adding the node once with
//!   `invocations_per_token > 1` (e.g. the shared KQ linear of Fig. 4
//!   processes each token twice: once for K, once for Q).

use std::collections::HashMap;

use crate::hls::module::{ModuleKind, ModuleRef};
use crate::hls::stream::StreamEdge;
use crate::hls::Resources;

/// Node id in a dataflow graph.
pub type NodeId = usize;

/// One hardware instance in the composed design.
pub struct Node {
    pub id: NodeId,
    pub module: ModuleRef,
    /// How many times this instance processes each token (temporal reuse:
    /// the Fig. 4 KQ linear has 2; a dedicated instance has 1).
    pub invocations_per_token: f64,
    /// Instance multiplicity: identical copies working in parallel
    /// (e.g. K-engine and V-engine). Scales resources and divides load.
    pub copies: u64,
}

// Manual: `ModuleRef` is `Arc<dyn ModuleTemplate>`; print the module's
// name instead of demanding Debug of every template.
impl std::fmt::Debug for Node {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Node")
            .field("id", &self.id)
            .field("module", &self.module.name())
            .field("invocations_per_token", &self.invocations_per_token)
            .field("copies", &self.copies)
            .finish()
    }
}

impl Node {
    /// Effective steady-state cycles this node spends per pipeline token.
    pub fn service_per_token(&self) -> f64 {
        self.module.service_cycles_per_token() * self.invocations_per_token
            / self.copies as f64
    }
}

/// The composed accelerator graph.
#[derive(Default)]
pub struct DataflowGraph {
    pub nodes: Vec<Node>,
    /// (producer, consumer, stream) triples.
    pub edges: Vec<(NodeId, NodeId, StreamEdge)>,
    names: HashMap<String, NodeId>,
}

impl std::fmt::Debug for DataflowGraph {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("DataflowGraph")
            .field("nodes", &self.nodes)
            .field("edges", &self.edges)
            .finish_non_exhaustive()
    }
}

impl DataflowGraph {
    pub fn new() -> Self {
        Self::default()
    }

    /// Add a spatially-instantiated module (the `invoke` of Fig. 4).
    pub fn invoke(&mut self, module: ModuleRef) -> NodeId {
        self.invoke_reused(module, 1.0, 1)
    }

    /// Add a temporally-reused module: one instance, `reuse` sequential
    /// roles per token (Fig. 4's `Linear_Layer_KQ_reused` has reuse = 2).
    pub fn invoke_reused(&mut self, module: ModuleRef, reuse: f64, copies: u64) -> NodeId {
        let id = self.nodes.len();
        self.names.insert(module.name().to_string(), id);
        self.nodes.push(Node { id, module, invocations_per_token: reuse, copies: copies.max(1) });
        id
    }

    /// Connect two nodes with a FIFO stream.
    pub fn connect(&mut self, from: NodeId, to: NodeId, stream: StreamEdge) {
        assert!(from < self.nodes.len() && to < self.nodes.len(), "bad node id");
        assert_ne!(from, to, "self-loops are not streamable");
        self.edges.push((from, to, stream));
    }

    pub fn node_by_name(&self, name: &str) -> Option<&Node> {
        self.names.get(name).map(|&id| &self.nodes[id])
    }

    /// Total fabric cost: module instances × copies + FIFO glue.
    pub fn resources(&self) -> Resources {
        let mut total = Resources::zero();
        for n in &self.nodes {
            total += n.module.resources() * n.copies as f64;
        }
        for (_, _, s) in &self.edges {
            total += s.resources();
        }
        total
    }

    /// Aggregate HBM traffic per token across all nodes.
    pub fn hbm_bytes_per_token(&self) -> f64 {
        self.nodes
            .iter()
            .map(|n| n.module.hbm_bytes_per_token() * n.invocations_per_token)
            .sum()
    }

    /// The steady-state pipeline bottleneck: max node service per token.
    /// (Spatial pipeline throughput = 1 / bottleneck.)
    pub fn bottleneck_cycles_per_token(&self) -> f64 {
        self.nodes.iter().map(|n| n.service_per_token()).fold(0.0, f64::max)
    }

    /// Sum of service times — the fully-serialized (temporal) latency per
    /// token; the spatial/temporal gap of Fig. 1 is the ratio of this to
    /// the bottleneck.
    pub fn serialized_cycles_per_token(&self) -> f64 {
        self.nodes.iter().map(|n| n.service_per_token()).sum()
    }

    /// Per-kind resource breakdown for Table IV-style reporting.
    pub fn kind_breakdown(&self) -> Vec<(ModuleKind, usize, Resources)> {
        let mut by_kind: HashMap<u8, (ModuleKind, usize, Resources)> = HashMap::new();
        for n in &self.nodes {
            let k = n.module.kind();
            let entry = by_kind
                .entry(k as u8)
                .or_insert((k, 0, Resources::zero()));
            entry.1 += n.copies as usize;
            entry.2 += n.module.resources() * n.copies as f64;
        }
        let mut v: Vec<_> = by_kind.into_values().collect();
        v.sort_by_key(|(k, _, _)| *k as u8);
        v
    }
}

#[cfg(test)]
mod tests {
    use std::sync::Arc;

    use super::*;
    use crate::config::Precision;
    use crate::hls::module::{NonLinear, NonLinearKind, PrefillLinear};

    fn linear(label: &str, tp: u64, wp: u64) -> ModuleRef {
        Arc::new(PrefillLinear::new(label, tp, wp, 256, 256, Precision::Int4))
    }

    #[test]
    fn temporal_reuse_halves_throughput_not_resources() {
        let mut spatial = DataflowGraph::new();
        let a = spatial.invoke(linear("a", 8, 32));
        let b = spatial.invoke(linear("b", 8, 32));
        spatial.connect(a, b, StreamEdge::activation(8));

        let mut temporal = DataflowGraph::new();
        temporal.invoke_reused(linear("ab", 8, 32), 2.0, 1);

        // same work per token when serialized…
        assert!((spatial.serialized_cycles_per_token()
            - temporal.serialized_cycles_per_token())
            .abs()
            < 1e-9);
        // …but the temporal design has half the PE resources
        assert!(temporal.resources().lut < 0.75 * spatial.resources().lut);
        // …and half the pipeline throughput
        assert!(temporal.bottleneck_cycles_per_token()
            > 1.9 * spatial.bottleneck_cycles_per_token());
    }

    #[test]
    fn bottleneck_is_slowest_stage() {
        let mut g = DataflowGraph::new();
        let a = g.invoke(linear("fast", 8, 64));
        let b = g.invoke(linear("slow", 8, 8));
        g.connect(a, b, StreamEdge::activation(8));
        let slow = g.node_by_name("slow").unwrap().service_per_token();
        assert_eq!(g.bottleneck_cycles_per_token(), slow);
    }

    #[test]
    fn copies_divide_load() {
        let mut g = DataflowGraph::new();
        g.invoke_reused(linear("dual", 8, 32), 1.0, 2);
        let single = linear("x", 8, 32).service_cycles_per_token();
        assert!((g.bottleneck_cycles_per_token() - single / 2.0).abs() < 1e-9);
    }

    #[test]
    fn nonlinear_nodes_compose() {
        let mut g = DataflowGraph::new();
        let l = g.invoke(linear("l", 8, 32));
        let r = g.invoke(Arc::new(NonLinear::new("rope", NonLinearKind::RoPE, 8, 64)));
        g.connect(l, r, StreamEdge::activation(8));
        assert_eq!(g.nodes.len(), 2);
        assert!(g.resources().dsp > 0.0);
    }
}
