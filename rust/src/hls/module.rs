//! Module templates — the core of the FlexLLM library (paper Table III).
//!
//! Each template exposes the paper's configurable parameters
//! (`token_parallelism`, `block_parallelism`, `weight_parallelism`,
//! `head_parallelism`, dtypes, dims) and reports three models:
//!
//! * **timing** — cycles per token-tile, assuming II=1 pipelines (the
//!   paper's stated optimization level), Eqs. 1 and 3;
//! * **resources** — fabric cost (see [`super::calibration`]);
//! * **bandwidth** — HBM bytes per processed token (Eq. 2).
//!
//! The dataflow simulator consumes these through the [`ModuleTemplate`]
//! trait at *token* granularity: `service_cycles_per_token` is the
//! steady-state initiation interval of the module for one token.

use std::sync::Arc;

use crate::config::Precision;
use crate::hls::calibration as cal;
use crate::hls::Resources;

/// Coarse classification used by the composition/report layers.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ModuleKind {
    Linear,
    Attention,
    NonLinear,
    Quant,
    Dequant,
    Fht,
    KvCache,
    Sampling,
}

impl ModuleKind {
    pub fn name(self) -> &'static str {
        match self {
            ModuleKind::Linear => "Linear",
            ModuleKind::Attention => "MHA",
            ModuleKind::NonLinear => "NonLinear",
            ModuleKind::Quant => "Quant",
            ModuleKind::Dequant => "Dequant",
            ModuleKind::Fht => "FHT",
            ModuleKind::KvCache => "KV_cache",
            ModuleKind::Sampling => "Sampling",
        }
    }
}

/// The common interface every FlexLLM module template implements.
pub trait ModuleTemplate: Send + Sync {
    /// Instance label (e.g. "pref_linear_kqvo").
    fn name(&self) -> &str;
    fn kind(&self) -> ModuleKind;
    /// Steady-state cycles to process ONE token through this module
    /// (fractional: a TP=8 module at 100 cycles/tile is 12.5 cy/token).
    fn service_cycles_per_token(&self) -> f64;
    /// Pipeline fill latency in cycles (first-token latency adder).
    fn fill_cycles(&self) -> u64 {
        64
    }
    /// Fabric cost of one hardware instance.
    fn resources(&self) -> Resources;
    /// Off-chip HBM bytes moved per processed token.
    fn hbm_bytes_per_token(&self) -> f64 {
        0.0
    }
    /// (parameter, value) pairs for Table III-style introspection.
    fn params(&self) -> Vec<(&'static str, String)>;
}

/// Shared handle used by composition graphs.
pub type ModuleRef = Arc<dyn ModuleTemplate>;

fn div_ceil(a: u64, b: u64) -> u64 {
    a.div_ceil(b.max(1))
}

// ---------------------------------------------------------------------------
// Linear layers
// ---------------------------------------------------------------------------

/// Prefill linear module: a TP×WP 2-D systolic array (paper Fig. 3(a)).
///
/// Timing: Eq. 1 — `T = tokens · d_in · d_out / (TP·WP)` cycles.
/// Bandwidth: Eq. 2 — weights stream at `B_W · WP` bytes/cycle; per token
/// that amortizes to `d_in·d_out·B_W / TP`.
#[derive(Debug, Clone)]
pub struct PrefillLinear {
    pub label: String,
    pub tp: u64,
    pub wp: u64,
    pub d_in: u64,
    pub d_out: u64,
    pub w_prec: Precision,
}

impl PrefillLinear {
    pub fn new(label: &str, tp: u64, wp: u64, d_in: u64, d_out: u64, w_prec: Precision) -> Self {
        assert!(tp > 0 && wp > 0, "parallelism must be positive");
        PrefillLinear { label: label.into(), tp, wp, d_in, d_out, w_prec }
    }

    /// Eq. 1 latency for a full tensor of `tokens` tokens, in cycles.
    pub fn latency_cycles(&self, tokens: u64) -> u64 {
        div_ceil(tokens, self.tp) * div_ceil(self.d_in * self.d_out, self.wp)
            + self.fill_cycles()
    }
}

impl ModuleTemplate for PrefillLinear {
    fn name(&self) -> &str {
        &self.label
    }
    fn kind(&self) -> ModuleKind {
        ModuleKind::Linear
    }
    fn service_cycles_per_token(&self) -> f64 {
        (self.d_in * self.d_out) as f64 / (self.tp * self.wp) as f64
    }
    fn fill_cycles(&self) -> u64 {
        self.d_in + self.wp.min(64) + 32
    }
    fn resources(&self) -> Resources {
        let pes = (self.tp * self.wp) as f64;
        let act_tile_bytes = (self.tp * self.d_in) as f64 * 2.0 * 2.0; // double-buffered fp16
        cal::pe_cost(self.w_prec) * pes
            + cal::weight_stream_buffers(self.wp, self.w_prec)
            + cal::uram_for_bytes(act_tile_bytes)
    }
    fn hbm_bytes_per_token(&self) -> f64 {
        (self.d_in * self.d_out) as f64 * self.w_prec.bytes() / self.tp as f64
    }
    fn params(&self) -> Vec<(&'static str, String)> {
        vec![
            ("dtype", self.w_prec.name().into()),
            ("token_parallelism", self.tp.to_string()),
            ("weight_parallelism", self.wp.to_string()),
            ("max_in_dim", self.d_in.to_string()),
            ("max_out_dim", self.d_out.to_string()),
        ]
    }
}

/// Decode linear module: BP sets of 1-D systolic arrays with WP/BP PEs
/// each (paper Fig. 3(b)). Timing: Eq. 3 — `T = tokens·d_in·d_out / WP`.
/// Weights cannot be shared across tokens, so every token streams the
/// full weight matrix: `d_in·d_out·B_W` bytes/token.
#[derive(Debug, Clone)]
pub struct DecodeLinear {
    pub label: String,
    pub bp: u64,
    pub wp: u64,
    pub d_in: u64,
    pub d_out: u64,
    pub w_prec: Precision,
    /// Number of identical submodules the engine is partitioned into for
    /// floorplanning (paper Sec. IV-B last paragraph).
    pub partitions: u64,
}

impl DecodeLinear {
    pub fn new(label: &str, bp: u64, wp: u64, d_in: u64, d_out: u64, w_prec: Precision) -> Self {
        assert!(bp > 0 && wp >= bp, "need WP ≥ BP ≥ 1");
        DecodeLinear { label: label.into(), bp, wp, d_in, d_out, w_prec, partitions: 1 }
    }

    pub fn with_partitions(mut self, parts: u64) -> Self {
        self.partitions = parts.max(1);
        self
    }

    pub fn latency_cycles(&self, tokens: u64) -> u64 {
        tokens * div_ceil(self.d_in * self.d_out, self.wp) + self.fill_cycles()
    }
}

impl ModuleTemplate for DecodeLinear {
    fn name(&self) -> &str {
        &self.label
    }
    fn kind(&self) -> ModuleKind {
        ModuleKind::Linear
    }
    fn service_cycles_per_token(&self) -> f64 {
        (self.d_in * self.d_out) as f64 / self.wp as f64
    }
    fn fill_cycles(&self) -> u64 {
        self.d_in / self.bp.max(1) + 64
    }
    fn resources(&self) -> Resources {
        let pes = self.wp as f64;
        // BP-way reduction trees: log2(WP/BP) adder stages per block
        let tree_luts = self.bp as f64
            * (self.wp / self.bp.max(1)) as f64
            * ((self.wp / self.bp.max(1)) as f64).log2().max(1.0)
            * 0.9;
        // partitioning duplicates stream plumbing per submodule
        let part_overhead =
            cal::weight_stream_buffers(self.wp / self.partitions, self.w_prec) * (self.partitions as f64);
        cal::pe_cost(self.w_prec) * pes
            + Resources { lut: tree_luts, ff: tree_luts * 1.1, ..Resources::zero() }
            + part_overhead
    }
    fn hbm_bytes_per_token(&self) -> f64 {
        (self.d_in * self.d_out) as f64 * self.w_prec.bytes()
    }
    fn params(&self) -> Vec<(&'static str, String)> {
        vec![
            ("dtype", self.w_prec.name().into()),
            ("block_parallelism", self.bp.to_string()),
            ("weight_parallelism", self.wp.to_string()),
            ("max_in_dim", self.d_in.to_string()),
            ("max_out_dim", self.d_out.to_string()),
            ("partitions", self.partitions.to_string()),
        ]
    }
}

// ---------------------------------------------------------------------------
// Attention
// ---------------------------------------------------------------------------

/// One MHA matmul engine (QKᵀ or PV) over the KV cache.
///
/// Prefill: per TP-tile the engine scans the full context —
/// `d_model·ctx / WP` cycles (the Eq. 4 max-term). Decode: one token scans
/// `ctx` — `d_model·ctx / WP` cycles (Eq. 6 max-term). KV stream traffic:
/// `ctx · d_kv · B_kv` bytes per token-tile element.
#[derive(Debug, Clone)]
pub struct MhaEngine {
    pub label: String,
    /// Tokens per tile: TP in prefill, 1 in decode.
    pub tile_tokens: u64,
    pub wp: u64,
    pub d_model: u64,
    pub d_kv: u64,
    /// Context length this engine is evaluated at (l_p, or l_p + l_d/2).
    pub ctx: u64,
    pub kv_prec: Precision,
    pub head_parallelism: u64,
}

impl MhaEngine {
    pub fn prefill(label: &str, tp: u64, wp: u64, d_model: u64, d_kv: u64, ctx: u64, hp: u64) -> Self {
        MhaEngine { label: label.into(), tile_tokens: tp, wp, d_model, d_kv, ctx,
                    kv_prec: Precision::Int8, head_parallelism: hp }
    }

    pub fn decode(label: &str, wp: u64, d_model: u64, d_kv: u64, avg_ctx: u64, hp: u64) -> Self {
        MhaEngine { label: label.into(), tile_tokens: 1, wp, d_model, d_kv, ctx: avg_ctx,
                    kv_prec: Precision::Int8, head_parallelism: hp }
    }
}

impl ModuleTemplate for MhaEngine {
    fn name(&self) -> &str {
        &self.label
    }
    fn kind(&self) -> ModuleKind {
        ModuleKind::Attention
    }
    fn service_cycles_per_token(&self) -> f64 {
        (self.d_model * self.ctx) as f64 / (self.wp * self.tile_tokens) as f64
    }
    fn fill_cycles(&self) -> u64 {
        self.d_model / self.head_parallelism.max(1) + 64
    }
    fn resources(&self) -> Resources {
        let pes = (self.tile_tokens * self.wp) as f64;
        let kv_tile = (self.d_kv * 512) as f64 * self.kv_prec.bytes(); // staging window
        cal::pe_cost(self.kv_prec) * pes
            + cal::weight_stream_buffers(self.wp, self.kv_prec)
            + cal::uram_for_bytes(kv_tile)
    }
    fn hbm_bytes_per_token(&self) -> f64 {
        (self.ctx * self.d_kv) as f64 * self.kv_prec.bytes() / self.tile_tokens as f64
    }
    fn params(&self) -> Vec<(&'static str, String)> {
        vec![
            ("dtype", self.kv_prec.name().into()),
            ("weight_parallelism", self.wp.to_string()),
            ("head_parallelism", self.head_parallelism.to_string()),
            ("max_seq_len", self.ctx.to_string()),
        ]
    }
}

// ---------------------------------------------------------------------------
// Non-linear layers
// ---------------------------------------------------------------------------

/// Which non-linear template (paper Table III row 2).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NonLinearKind {
    RoPE,
    Softmax,
    RmsNorm,
    Swish,
    Gate,
    Residual,
}

impl NonLinearKind {
    /// Pipelined passes over the channel dim (II=1 per element per lane).
    fn passes(self) -> f64 {
        match self {
            NonLinearKind::RoPE => 0.5,     // hd/2 rotations
            NonLinearKind::Softmax => 3.0,  // max, exp+sum, normalize
            NonLinearKind::RmsNorm => 2.0,  // reduce, scale
            NonLinearKind::Swish => 1.0,
            NonLinearKind::Gate => 1.0,
            NonLinearKind::Residual => 1.0,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            NonLinearKind::RoPE => "RoPE",
            NonLinearKind::Softmax => "Softmax",
            NonLinearKind::RmsNorm => "RMSNorm",
            NonLinearKind::Swish => "Swish",
            NonLinearKind::Gate => "Gate",
            NonLinearKind::Residual => "Residual",
        }
    }
}

/// A non-linear module with `lanes` parallel token lanes (TP in prefill,
/// BP in decode — "non-linear overheads scale mainly with TP", Sec. IV-B).
#[derive(Debug, Clone)]
pub struct NonLinear {
    pub label: String,
    pub which: NonLinearKind,
    pub lanes: u64,
    pub io_dim: u64,
}

impl NonLinear {
    pub fn new(label: &str, which: NonLinearKind, lanes: u64, io_dim: u64) -> Self {
        NonLinear { label: label.into(), which, lanes: lanes.max(1), io_dim }
    }
}

impl ModuleTemplate for NonLinear {
    fn name(&self) -> &str {
        &self.label
    }
    fn kind(&self) -> ModuleKind {
        ModuleKind::NonLinear
    }
    fn service_cycles_per_token(&self) -> f64 {
        self.which.passes() * self.io_dim as f64 / self.lanes as f64
    }
    fn fill_cycles(&self) -> u64 {
        32
    }
    fn resources(&self) -> Resources {
        cal::nonlinear_lane_cost() * cal::lane_scale(self.lanes)
    }
    fn params(&self) -> Vec<(&'static str, String)> {
        vec![
            ("kind", self.which.name().into()),
            ("lanes(TP/BP)", self.lanes.to_string()),
            ("io_dim", self.io_dim.to_string()),
        ]
    }
}

// ---------------------------------------------------------------------------
// Quantization modules
// ---------------------------------------------------------------------------

/// Quantizer template (paper Fig. 3(c), Quant Library row 1).
#[derive(Debug, Clone)]
pub struct Quantizer {
    pub label: String,
    pub dynamic: bool,
    pub symmetric: bool,
    pub per_token: bool,
    pub lanes: u64,
    pub io_dim: u64,
    pub out_bits: u32,
}

impl Quantizer {
    pub fn new(label: &str, dynamic: bool, symmetric: bool, per_token: bool,
               lanes: u64, io_dim: u64, out_bits: u32) -> Self {
        Quantizer { label: label.into(), dynamic, symmetric, per_token,
                    lanes: lanes.max(1), io_dim, out_bits }
    }
}

impl ModuleTemplate for Quantizer {
    fn name(&self) -> &str {
        &self.label
    }
    fn kind(&self) -> ModuleKind {
        ModuleKind::Quant
    }
    fn service_cycles_per_token(&self) -> f64 {
        // dynamic needs an extra min/max pass before the rounding pass
        let passes = if self.dynamic { 2.0 } else { 1.0 };
        passes * self.io_dim as f64 / self.lanes as f64
    }
    fn resources(&self) -> Resources {
        cal::quant_lane_cost(self.dynamic) * cal::lane_scale(self.lanes)
    }
    fn params(&self) -> Vec<(&'static str, String)> {
        vec![
            ("in_quant_bit", self.out_bits.to_string()),
            ("in_quant_type", if self.symmetric { "sym" } else { "asym" }.into()),
            ("in_quant_granularity", if self.per_token { "per-token" } else { "per-tensor" }.into()),
            ("dynamic", self.dynamic.to_string()),
            ("lanes(TP/BP)", self.lanes.to_string()),
        ]
    }
}

/// Dequantizer template (Quant Library row 2): reconstructs FP from the
/// integer accumulator using per-channel weight scales and column sums.
#[derive(Debug, Clone)]
pub struct Dequantizer {
    pub label: String,
    pub lanes: u64,
    pub io_dim: u64,
    pub w_per_channel: bool,
}

impl Dequantizer {
    pub fn new(label: &str, lanes: u64, io_dim: u64, w_per_channel: bool) -> Self {
        Dequantizer { label: label.into(), lanes: lanes.max(1), io_dim, w_per_channel }
    }
}

impl ModuleTemplate for Dequantizer {
    fn name(&self) -> &str {
        &self.label
    }
    fn kind(&self) -> ModuleKind {
        ModuleKind::Dequant
    }
    fn service_cycles_per_token(&self) -> f64 {
        self.io_dim as f64 / self.lanes as f64
    }
    fn resources(&self) -> Resources {
        // aux-data buffers (w_scale + col_sum per channel) in BRAM
        let aux = Resources { bram: (self.io_dim as f64 * 8.0 / 4096.0).ceil(), ..Resources::zero() };
        cal::quant_lane_cost(false) * cal::lane_scale(self.lanes) + aux
    }
    fn params(&self) -> Vec<(&'static str, String)> {
        vec![
            ("w_quant_granularity", if self.w_per_channel { "per-channel" } else { "per-tensor" }.into()),
            ("lanes(TP/BP)", self.lanes.to_string()),
            ("io_dim", self.io_dim.to_string()),
        ]
    }
}

/// Fast Hadamard Transform module (outlier handling; fully pipelined
/// butterfly network, one token per `io_dim/lanes` cycles).
#[derive(Debug, Clone)]
pub struct FhtModule {
    pub label: String,
    pub lanes: u64,
    pub io_dim: u64,
}

impl FhtModule {
    pub fn new(label: &str, lanes: u64, io_dim: u64) -> Self {
        assert!(io_dim.is_power_of_two(), "FHT dim must be a power of two");
        FhtModule { label: label.into(), lanes: lanes.max(1), io_dim }
    }
}

impl ModuleTemplate for FhtModule {
    fn name(&self) -> &str {
        &self.label
    }
    fn kind(&self) -> ModuleKind {
        ModuleKind::Fht
    }
    fn service_cycles_per_token(&self) -> f64 {
        self.io_dim as f64 / self.lanes as f64
    }
    fn fill_cycles(&self) -> u64 {
        (self.io_dim as f64).log2() as u64 + 16
    }
    fn resources(&self) -> Resources {
        cal::fht_lane_cost(self.io_dim) * cal::lane_scale(self.lanes)
    }
    fn params(&self) -> Vec<(&'static str, String)> {
        vec![("lanes(TP/BP)", self.lanes.to_string()), ("io_dim", self.io_dim.to_string())]
    }
}

/// KV-cache streaming module: writes new K/V to HBM and feeds the MHA
/// engines. Pure traffic/buffering; negligible compute.
#[derive(Debug, Clone)]
pub struct KvCache {
    pub label: String,
    pub d_kv: u64,
    pub kv_prec: Precision,
}

impl KvCache {
    pub fn new(label: &str, d_kv: u64, kv_prec: Precision) -> Self {
        KvCache { label: label.into(), d_kv, kv_prec }
    }
}

impl ModuleTemplate for KvCache {
    fn name(&self) -> &str {
        &self.label
    }
    fn kind(&self) -> ModuleKind {
        ModuleKind::KvCache
    }
    fn service_cycles_per_token(&self) -> f64 {
        // write K and V rows for one token through a wide AXI port
        (2 * self.d_kv) as f64 * self.kv_prec.bytes() / 64.0
    }
    fn resources(&self) -> Resources {
        Resources { lut: 6_000.0, ff: 9_000.0, bram: 16.0, ..Resources::zero() }
    }
    fn hbm_bytes_per_token(&self) -> f64 {
        (2 * self.d_kv) as f64 * self.kv_prec.bytes()
    }
    fn params(&self) -> Vec<(&'static str, String)> {
        vec![("dtype", self.kv_prec.name().into()), ("d_kv", self.d_kv.to_string())]
    }
}

/// Greedy / top-k sampling over the vocabulary logits.
#[derive(Debug, Clone)]
pub struct Sampling {
    pub label: String,
    pub vocab: u64,
    pub lanes: u64,
}

impl Sampling {
    pub fn new(label: &str, vocab: u64, lanes: u64) -> Self {
        Sampling { label: label.into(), vocab, lanes: lanes.max(1) }
    }
}

impl ModuleTemplate for Sampling {
    fn name(&self) -> &str {
        &self.label
    }
    fn kind(&self) -> ModuleKind {
        ModuleKind::Sampling
    }
    fn service_cycles_per_token(&self) -> f64 {
        self.vocab as f64 / self.lanes as f64
    }
    fn resources(&self) -> Resources {
        Resources { lut: 2_500.0 * cal::lane_scale(self.lanes),
                    ff: 2_000.0 * cal::lane_scale(self.lanes),
                    ..Resources::zero() }
    }
    fn params(&self) -> Vec<(&'static str, String)> {
        vec![("vocab", self.vocab.to_string()), ("lanes", self.lanes.to_string())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eq1_prefill_linear_latency() {
        // Eq. 1: tokens·d_in·d_out/(TP·WP)
        let m = PrefillLinear::new("l", 8, 24, 2048, 512, Precision::Int4);
        let t = m.latency_cycles(1024) - m.fill_cycles();
        assert_eq!(t, (1024 / 8) * (2048 * 512 / 24 + 1)); // ceil division
        assert!((m.service_cycles_per_token() - 2048.0 * 512.0 / (8.0 * 24.0)).abs() < 1e-9);
    }

    #[test]
    fn eq3_decode_linear_latency() {
        let m = DecodeLinear::new("l", 16, 1024, 2048, 8192, Precision::Int4);
        let t = m.latency_cycles(1) - m.fill_cycles();
        assert_eq!(t, 2048 * 8192 / 1024);
    }

    #[test]
    fn eq2_bandwidth_per_cycle() {
        // BW = B_W · WP bytes/cycle ⇒ per token: d_in·d_out·B_W/TP over
        // d_in·d_out/(TP·WP) cycles.
        let m = PrefillLinear::new("l", 8, 96, 2048, 8192, Precision::Int4);
        let bytes_per_cycle = m.hbm_bytes_per_token() / m.service_cycles_per_token();
        assert!((bytes_per_cycle - 0.5 * 96.0).abs() < 1e-9);
    }

    #[test]
    fn decode_streams_full_weights_every_token() {
        let m = DecodeLinear::new("l", 16, 1024, 2048, 2048, Precision::Int4);
        assert!((m.hbm_bytes_per_token() - 2048.0 * 2048.0 * 0.5).abs() < 1e-9);
    }

    #[test]
    fn mha_scales_with_context() {
        let a = MhaEngine::decode("m", 256, 2048, 512, 1024, 8);
        let b = MhaEngine::decode("m", 256, 2048, 512, 2048, 8);
        assert!((b.service_cycles_per_token() / a.service_cycles_per_token() - 2.0).abs() < 1e-9);
    }

    #[test]
    fn dynamic_quant_costs_more_than_static() {
        let dy = Quantizer::new("q", true, false, true, 8, 2048, 4);
        let st = Quantizer::new("q", false, true, false, 8, 2048, 8);
        assert!(dy.service_cycles_per_token() > st.service_cycles_per_token());
        assert!(dy.resources().lut > st.resources().lut);
    }

    #[test]
    fn int4_pe_cheaper_in_dsp_than_fp16() {
        let i4 = PrefillLinear::new("a", 8, 32, 256, 256, Precision::Int4);
        let f16 = PrefillLinear::new("b", 8, 32, 256, 256, Precision::Fp16);
        assert!(i4.resources().dsp < f16.resources().dsp);
    }

    #[test]
    fn fht_requires_power_of_two() {
        let ok = std::panic::catch_unwind(|| FhtModule::new("f", 4, 8192));
        assert!(ok.is_ok());
        let bad = std::panic::catch_unwind(|| FhtModule::new("f", 4, 8191));
        assert!(bad.is_err());
    }
}
