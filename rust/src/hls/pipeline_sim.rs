//! Token-granularity dataflow pipeline simulator.
//!
//! Computes the makespan, per-node busy/stall breakdown and achieved
//! utilization of a composed [`DataflowGraph`] processing `n_tokens`
//! tokens. This is what makes the temporal-vs-spatial-vs-hybrid story of
//! Fig. 1 *emerge* instead of being asserted:
//!
//! * a **spatial** design's throughput is gated by its slowest stage
//!   (pipeline stalls when kernel latencies are unbalanced);
//! * a **temporal** design is gated by the serialized sum of services;
//! * a **hybrid** design lands in between, with reuse only where the
//!   pipeline had slack.
//!
//! Model: streams are 1:1 at token granularity; node `i` starts token `k`
//! when (a) it finished token `k-1` and (b) every predecessor finished
//! token `k`. Dependency edges may carry a *lag*: a self-recurrent decode
//! dependency (token k needs token k-1's output) is lag 1. FIFO depths
//! shift transients only and are accounted as resources, not simulated.

use crate::hls::dataflow::{DataflowGraph, NodeId};

/// Per-node outcome of a simulation run.
#[derive(Debug, Clone)]
pub struct NodeStats {
    pub name: String,
    pub busy_cycles: f64,
    pub stall_cycles: f64,
    /// busy / (busy + stall): the paper's "runtime hardware utilization".
    pub utilization: f64,
}

/// Whole-run outcome.
#[derive(Debug, Clone)]
pub struct SimResult {
    pub makespan_cycles: f64,
    pub nodes: Vec<NodeStats>,
    /// Aggregate utilization (busy-weighted mean over nodes).
    pub mean_utilization: f64,
    /// HBM bytes moved per simulated token (from the graph model).
    pub hbm_bytes_per_token: f64,
}

impl SimResult {
    pub fn seconds(&self, freq_hz: f64) -> f64 {
        self.makespan_cycles / freq_hz
    }

    /// Average HBM bandwidth demand over the run at `freq_hz`.
    pub fn avg_bandwidth(&self, freq_hz: f64, n_tokens: u64) -> f64 {
        self.hbm_bytes_per_token * n_tokens as f64 / self.seconds(freq_hz)
    }
}

/// Extra dependency constraints beyond the stream edges.
#[derive(Debug, Clone, Copy)]
pub struct Dependency {
    pub from: NodeId,
    pub to: NodeId,
    /// Token lag: `to` processing token k waits for `from` finishing
    /// token `k - lag`. lag = 0 is a plain same-token dependency; lag = 1
    /// models the autoregressive decode recurrence.
    pub lag: u64,
}

/// Simulate `graph` processing `n_tokens` tokens.
///
/// `extra_deps` adds non-stream dependencies (autoregressive recurrence,
/// barrier-style joins). Runs in O(nodes · n_tokens).
pub fn simulate(graph: &DataflowGraph, n_tokens: u64, extra_deps: &[Dependency]) -> SimResult {
    let n_nodes = graph.nodes.len();
    let n = n_tokens as usize;
    assert!(n_nodes > 0, "empty graph");

    // adjacency: for each node, (pred, lag) pairs
    let mut preds: Vec<Vec<(NodeId, u64)>> = vec![Vec::new(); n_nodes];
    for (from, to, _) in &graph.edges {
        preds[*to].push((*from, 0));
    }
    for d in extra_deps {
        preds[d.to].push((d.from, d.lag));
    }

    // finish[i][k] = cycle when node i completes token k
    let mut finish = vec![vec![0.0f64; n]; n_nodes];
    let mut busy = vec![0.0f64; n_nodes];
    let mut stall = vec![0.0f64; n_nodes];

    // topological order (graph is a DAG over stream edges; lagged deps
    // may create cycles, which the token index unrolls)
    let order = topo_order(n_nodes, &graph.edges);

    for k in 0..n {
        for &i in &order {
            let service = graph.nodes[i].service_per_token();
            let fill = if k == 0 { graph.nodes[i].module.fill_cycles() as f64 } else { 0.0 };
            let mut ready = if k > 0 { finish[i][k - 1] } else { 0.0 };
            for &(p, lag) in &preds[i] {
                let dep_k = k as i64 - lag as i64;
                if dep_k >= 0 {
                    ready = ready.max(finish[p][dep_k as usize]);
                }
            }
            let own_prev = if k > 0 { finish[i][k - 1] } else { 0.0 };
            stall[i] += (ready - own_prev).max(0.0);
            busy[i] += service;
            finish[i][k] = ready + fill + service;
        }
    }

    let makespan = finish
        .iter()
        .map(|f| f[n - 1])
        .fold(0.0, f64::max);

    let nodes: Vec<NodeStats> = (0..n_nodes)
        .map(|i| {
            let total = busy[i] + stall[i];
            NodeStats {
                name: graph.nodes[i].module.name().to_string(),
                busy_cycles: busy[i],
                stall_cycles: stall[i],
                utilization: if total > 0.0 { busy[i] / total } else { 1.0 },
            }
        })
        .collect();

    let total_busy: f64 = busy.iter().sum();
    let mean_utilization = if makespan > 0.0 {
        total_busy / (makespan * n_nodes as f64)
    } else {
        1.0
    };

    SimResult {
        makespan_cycles: makespan,
        nodes,
        mean_utilization,
        hbm_bytes_per_token: graph.hbm_bytes_per_token(),
    }
}

/// Simulate `graph` processing `n_tokens` **autoregressively**: the
/// graph's tail node feeds its head at lag 1, so token `k` cannot enter
/// the pipeline before token `k-1` has left it. This is the cost of
/// running decode on a *spatial* design — the recurrence drains the
/// pipeline every token, collapsing throughput toward the serialized
/// sum of stage services (Fig. 1(d/e)). [`crate::arch::DecodeArch`]
/// uses it for its native temporal engine and
/// [`crate::arch::PrefillArch::recurrent_decode_latency_s`] uses it to
/// price decode *fallback* on a prefill-specialized pipeline.
pub fn simulate_recurrent(graph: &DataflowGraph, n_tokens: u64) -> SimResult {
    assert!(!graph.nodes.is_empty(), "empty graph");
    let last = graph.nodes.len() - 1;
    let dep = Dependency { from: last, to: 0, lag: 1 };
    simulate(graph, n_tokens, &[dep])
}

/// Kahn topological sort over stream edges; falls back to insertion order
/// for nodes in (erroneous) cycles so the simulator still terminates.
fn topo_order(n_nodes: usize, edges: &[(NodeId, NodeId, crate::hls::stream::StreamEdge)]) -> Vec<usize> {
    let mut indeg = vec![0usize; n_nodes];
    let mut adj: Vec<Vec<usize>> = vec![Vec::new(); n_nodes];
    for (f, t, _) in edges {
        adj[*f].push(*t);
        indeg[*t] += 1;
    }
    let mut queue: Vec<usize> = (0..n_nodes).filter(|&i| indeg[i] == 0).collect();
    let mut order = Vec::with_capacity(n_nodes);
    let mut qi = 0;
    while qi < queue.len() {
        let u = queue[qi];
        qi += 1;
        order.push(u);
        for &v in &adj[u] {
            indeg[v] -= 1;
            if indeg[v] == 0 {
                queue.push(v);
            }
        }
    }
    if order.len() < n_nodes {
        for i in 0..n_nodes {
            if !order.contains(&i) {
                order.push(i);
            }
        }
    }
    order
}

#[cfg(test)]
mod tests {
    use std::sync::Arc;

    use super::*;
    use crate::config::Precision;
    use crate::hls::dataflow::DataflowGraph;
    use crate::hls::module::PrefillLinear;
    use crate::hls::stream::StreamEdge;

    fn linear(label: &str, tp: u64, wp: u64) -> Arc<PrefillLinear> {
        Arc::new(PrefillLinear::new(label, tp, wp, 64, 64, Precision::Int4))
    }

    #[test]
    fn balanced_pipeline_reaches_stage_throughput() {
        let mut g = DataflowGraph::new();
        let a = g.invoke(linear("a", 8, 16));
        let b = g.invoke(linear("b", 8, 16));
        g.connect(a, b, StreamEdge::activation(8));
        let n = 4096;
        let r = simulate(&g, n, &[]);
        let per_tok = g.bottleneck_cycles_per_token();
        // makespan ≈ n · bottleneck (+ fill); within 5%
        assert!((r.makespan_cycles / (n as f64 * per_tok) - 1.0).abs() < 0.05,
                "makespan {} vs bound {}", r.makespan_cycles, n as f64 * per_tok);
    }

    #[test]
    fn unbalanced_pipeline_stalls_fast_stage() {
        let mut g = DataflowGraph::new();
        let fast = g.invoke(linear("fast", 8, 64));
        let slow = g.invoke(linear("slow", 8, 4));
        g.connect(slow, fast, StreamEdge::activation(8));
        let r = simulate(&g, 1024, &[]);
        let fast_stats = r.nodes.iter().find(|s| s.name == "fast").unwrap();
        // the fast stage idles most of the time — Fig. 1(d/e) stall story
        assert!(fast_stats.utilization < 0.2, "util = {}", fast_stats.utilization);
    }

    #[test]
    fn autoregressive_lag_serializes() {
        // a -> b with b feeding back to a at lag 1 (decode recurrence):
        // throughput collapses to the serialized sum.
        let mut g = DataflowGraph::new();
        let a = g.invoke(linear("a", 1, 16));
        let b = g.invoke(linear("b", 1, 16));
        g.connect(a, b, StreamEdge::activation(1));
        let n = 256;
        let dep = Dependency { from: b, to: a, lag: 1 };
        let serial = simulate(&g, n, &[dep]);
        let pipe = simulate(&g, n, &[]);
        let sum = g.serialized_cycles_per_token();
        assert!(serial.makespan_cycles >= 0.95 * n as f64 * sum);
        assert!(pipe.makespan_cycles < 0.6 * serial.makespan_cycles);
    }

    #[test]
    fn simulate_recurrent_matches_explicit_lag_dep() {
        let mut g = DataflowGraph::new();
        let a = g.invoke(linear("a", 1, 16));
        let b = g.invoke(linear("b", 1, 16));
        g.connect(a, b, StreamEdge::activation(1));
        let dep = Dependency { from: b, to: a, lag: 1 };
        let explicit = simulate(&g, 64, &[dep]);
        let helper = simulate_recurrent(&g, 64);
        assert_eq!(explicit.makespan_cycles, helper.makespan_cycles);
        // the recurrence must cost more than the free-running pipeline
        assert!(helper.makespan_cycles > simulate(&g, 64, &[]).makespan_cycles);
    }

    #[test]
    fn utilization_bounded() {
        let mut g = DataflowGraph::new();
        let a = g.invoke(linear("a", 8, 16));
        let b = g.invoke(linear("b", 8, 32));
        g.connect(a, b, StreamEdge::activation(8));
        let r = simulate(&g, 512, &[]);
        for s in &r.nodes {
            assert!(s.utilization > 0.0 && s.utilization <= 1.0);
        }
        assert!(r.mean_utilization > 0.0 && r.mean_utilization <= 1.0);
    }
}
