//! FPGA fabric resource accounting (CLB / DSP / LUT / FF / BRAM / URAM).
//!
//! Every module template reports a [`Resources`] vector; composition sums
//! them; [`crate::config::DeviceConfig::utilization`] normalizes against
//! the device pool. Units match the AMD datasheets: BRAM in 36Kb blocks,
//! URAM in 288Kb blocks, CLB as slice count.

use std::ops::{Add, AddAssign, Mul};


/// A resource vector (usage or capacity).
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct Resources {
    pub clb: f64,
    pub dsp: f64,
    pub lut: f64,
    pub ff: f64,
    pub bram: f64,
    pub uram: f64,
}

impl Resources {
    pub const fn zero() -> Self {
        Resources { clb: 0.0, dsp: 0.0, lut: 0.0, ff: 0.0, bram: 0.0, uram: 0.0 }
    }

    /// The binding (maximum) utilization across classes — used for fit
    /// checks after normalization.
    pub fn max_class(&self) -> f64 {
        self.clb
            .max(self.dsp)
            .max(self.lut)
            .max(self.ff)
            .max(self.bram)
            .max(self.uram)
    }

    /// CLBs are not modeled independently: AMD packs 8 LUTs + 16 FFs per
    /// CLB slice; observed designs close at ~55% LUT packing efficiency.
    /// Calling this derives the CLB estimate from LUT/FF pressure.
    pub fn with_derived_clb(mut self) -> Self {
        let by_lut = self.lut / (8.0 * 0.55);
        let by_ff = self.ff / (16.0 * 0.70);
        self.clb = by_lut.max(by_ff);
        self
    }

    pub fn is_finite(&self) -> bool {
        [self.clb, self.dsp, self.lut, self.ff, self.bram, self.uram]
            .iter()
            .all(|v| v.is_finite() && *v >= 0.0)
    }
}

impl Add for Resources {
    type Output = Resources;
    fn add(self, o: Resources) -> Resources {
        Resources {
            clb: self.clb + o.clb,
            dsp: self.dsp + o.dsp,
            lut: self.lut + o.lut,
            ff: self.ff + o.ff,
            bram: self.bram + o.bram,
            uram: self.uram + o.uram,
        }
    }
}

impl AddAssign for Resources {
    fn add_assign(&mut self, o: Resources) {
        *self = *self + o;
    }
}

impl Mul<f64> for Resources {
    type Output = Resources;
    fn mul(self, k: f64) -> Resources {
        Resources {
            clb: self.clb * k,
            dsp: self.dsp * k,
            lut: self.lut * k,
            ff: self.ff * k,
            bram: self.bram * k,
            uram: self.uram * k,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn add_and_scale() {
        let a = Resources { clb: 1.0, dsp: 2.0, lut: 3.0, ff: 4.0, bram: 5.0, uram: 6.0 };
        let b = a * 2.0 + a;
        assert_eq!(b.dsp, 6.0);
        assert_eq!(b.uram, 18.0);
        assert_eq!(b.max_class(), 18.0);
    }

    #[test]
    fn derived_clb_tracks_lut_pressure() {
        let r = Resources { lut: 440_000.0, ff: 100_000.0, ..Resources::zero() }
            .with_derived_clb();
        assert!(r.clb > 90_000.0 && r.clb < 110_000.0, "clb = {}", r.clb);
    }
}
