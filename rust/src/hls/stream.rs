//! On-chip FIFO stream model (the `tapa::stream` analog).
//!
//! Streams connect module instances in spatial-dataflow composition. The
//! simulator treats them as depth-bounded queues only for resource
//! accounting (BRAM/LUTRAM); throughput analysis uses the steady-state
//! service rates (see `pipeline_sim`), where a deeper FIFO only shifts
//! transients, not the bottleneck.

use crate::hls::Resources;

/// A typed stream edge between two module instances.
#[derive(Debug, Clone)]
pub struct StreamEdge {
    /// Vector width in elements per beat (e.g. `vector<float, TP>`).
    pub width_elems: u64,
    /// Bytes per element.
    pub elem_bytes: f64,
    /// FIFO depth in beats.
    pub depth: u64,
}

impl StreamEdge {
    pub fn new(width_elems: u64, elem_bytes: f64, depth: u64) -> Self {
        StreamEdge { width_elems, elem_bytes, depth: depth.max(2) }
    }

    /// Default stream sizing used by composed architectures.
    pub fn activation(width_elems: u64) -> Self {
        StreamEdge::new(width_elems, 2.0, 64)
    }

    /// FIFO storage in bytes.
    pub fn bytes(&self) -> f64 {
        self.width_elems as f64 * self.elem_bytes * self.depth as f64
    }

    /// Fabric cost: shallow FIFOs map to LUTRAM, deep ones to BRAM.
    pub fn resources(&self) -> Resources {
        let bytes = self.bytes();
        if self.depth <= 32 {
            Resources { lut: bytes / 32.0 + 24.0, ff: 48.0, ..Resources::zero() }
        } else {
            Resources { bram: (bytes / 4_608.0).ceil().max(0.5), lut: 40.0, ff: 60.0,
                        ..Resources::zero() }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deep_fifos_use_bram() {
        let shallow = StreamEdge::new(8, 2.0, 16);
        let deep = StreamEdge::new(8, 2.0, 512);
        assert_eq!(shallow.resources().bram, 0.0);
        assert!(deep.resources().bram >= 1.0);
    }

    #[test]
    fn depth_clamped_to_two() {
        assert_eq!(StreamEdge::new(1, 1.0, 0).depth, 2);
    }
}
