//! Design-space exploration: the paper's ILP parameter tuning.
//!
//! "We tune these parameters via Integer Linear Programming (ILP) under
//! hardware constraints (resources and memory bandwidth) to minimize T_p
//! / T_d" (Sec. IV-B). The objective (Eqs. 4/6) is linear in the
//! reciprocal parallelism variables over a small discrete grid, so exact
//! minimization by enumeration with constraint pruning ("branch and
//! bound" degenerate case) matches the ILP optimum. We implement exactly
//! that: exhaustive search with feasibility pruning, which is both exact
//! and fast (< 1 ms per stage) on the paper's grid sizes.

use crate::arch::{DecodeArch, DecodeConfig, PrefillArch, PrefillConfig};
use crate::config::{DeviceConfig, ModelDims};

/// Resource headroom for P&R closure (fraction of each class usable).
pub const HEADROOM: f64 = 0.88;

/// Decode bandwidth oversubscription: Eq. 7 sums the *peak* demand of the
/// INT4 linear engine and both MHA engines, but they alternate within a
/// token (the linear engine stalls during the attention phase), so the
/// sustained demand is lower. The paper's own V80 point (WPint4=4096,
/// WPmha=1024 → 1.23 TB/s peak vs 820 GB/s HBM) is only feasible under
/// this interpretation; 1.6× covers it with margin.
pub const DECODE_BW_OVERSUB: f64 = 1.6;

/// Outcome of a DSE run.
#[derive(Debug, Clone)]
pub struct DseResult<C> {
    pub best: C,
    pub latency_s: f64,
    pub evaluated: usize,
    pub feasible: usize,
    /// (config, latency) Pareto-ish trail for reporting.
    pub trail: Vec<(C, f64)>,
}

/// Candidate grids (multiples the paper's configs live on).
fn tp_grid() -> Vec<u64> {
    vec![2, 4, 8, 16, 32]
}
fn wp_grid() -> Vec<u64> {
    vec![8, 16, 24, 32, 48, 64, 96, 128, 192, 256]
}
fn wide_wp_grid() -> Vec<u64> {
    vec![128, 256, 512, 1024, 2048, 4096, 8192]
}
fn bp_grid() -> Vec<u64> {
    vec![4, 8, 16, 32, 64]
}

/// Tune the prefill architecture for `l_p`-token prompts on `device`.
pub fn tune_prefill(model: &ModelDims, device: &DeviceConfig, l_p: u64) -> DseResult<PrefillConfig> {
    let mut best: Option<(PrefillConfig, f64)> = None;
    let mut evaluated = 0;
    let mut feasible = 0;
    let mut trail = Vec::new();
    for &tp in &tp_grid() {
        for &wp_kqvo in &wp_grid() {
            for &wp_mha in &wp_grid() {
                for &wp_ffn in &wp_grid() {
                    evaluated += 1;
                    let cfg = PrefillConfig { tp, wp_kqvo, wp_mha, wp_ffn };
                    let arch = PrefillArch::new(cfg, model.clone(), device.clone());
                    // constraints: resources fit + Eq. 5 bandwidth under cap
                    if !device.fits(&arch.resources, HEADROOM)
                        || arch.peak_bandwidth() > device.hbm_bw
                    {
                        continue;
                    }
                    feasible += 1;
                    let t = arch.analytic_latency_s(l_p);
                    if best.as_ref().map(|(_, b)| t < *b).unwrap_or(true) {
                        trail.push((cfg, t));
                        best = Some((cfg, t));
                    }
                }
            }
        }
    }
    let (best, latency_s) = best.expect("no feasible prefill configuration");
    DseResult { best, latency_s, evaluated, feasible, trail }
}

/// Tune the decode architecture for a [l_p, l_d] workload on `device`.
pub fn tune_decode(
    model: &ModelDims,
    device: &DeviceConfig,
    l_p: u64,
    l_d: u64,
) -> DseResult<DecodeConfig> {
    let mut best: Option<(DecodeConfig, f64)> = None;
    let mut evaluated = 0;
    let mut feasible = 0;
    let mut trail = Vec::new();
    for &bp in &bp_grid() {
        for &wp_int4 in &wide_wp_grid() {
            if wp_int4 < bp {
                continue;
            }
            for &wp_mha in &wp_grid().iter().copied().chain([512, 1024]).collect::<Vec<_>>() {
                evaluated += 1;
                let cfg = DecodeConfig { bp, wp_int4, wp_mha };
                let arch = DecodeArch::new(cfg, model.clone(), device.clone());
                if !device.fits(&arch.resources, HEADROOM)
                    || arch.peak_bandwidth() > device.hbm_bw * DECODE_BW_OVERSUB
                {
                    continue;
                }
                feasible += 1;
                let t = arch.analytic_latency_s(l_p, l_d);
                if best.as_ref().map(|(_, b)| t < *b).unwrap_or(true) {
                    trail.push((cfg, t));
                    best = Some((cfg, t));
                }
            }
        }
    }
    let (best, latency_s) = best.expect("no feasible decode configuration");
    DseResult { best, latency_s, evaluated, feasible, trail }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prefill_dse_finds_near_paper_point() {
        let model = ModelDims::llama32_1b();
        let dev = DeviceConfig::u280();
        let r = tune_prefill(&model, &dev, 1024);
        // the found optimum must be at least as good as the paper's config
        let paper = PrefillArch::new(PrefillConfig::u280_paper(), model.clone(), dev.clone());
        assert!(r.latency_s <= paper.analytic_latency_s(1024) * 1.02,
                "dse {} vs paper {}", r.latency_s, paper.analytic_latency_s(1024));
        assert!(r.feasible > 0 && r.feasible <= r.evaluated);
    }

    #[test]
    fn decode_dse_finds_near_paper_point() {
        let model = ModelDims::llama32_1b();
        let dev = DeviceConfig::u280();
        let r = tune_decode(&model, &dev, 1024, 1024);
        let paper = DecodeArch::new(DecodeConfig::u280_paper(), model.clone(), dev.clone());
        assert!(r.latency_s <= paper.analytic_latency_s(1024, 1024) * 1.02);
    }

    #[test]
    fn dse_respects_bandwidth_constraint() {
        let model = ModelDims::llama32_1b();
        let dev = DeviceConfig::u280();
        let r = tune_decode(&model, &dev, 512, 512);
        let arch = DecodeArch::new(r.best, model, dev.clone());
        assert!(arch.peak_bandwidth() <= dev.hbm_bw * DECODE_BW_OVERSUB);
    }

    #[test]
    fn v80_optimum_wider_than_u280() {
        let model = ModelDims::llama32_1b();
        let u = tune_decode(&model, &DeviceConfig::u280(), 1024, 1024);
        let v = tune_decode(&model, &DeviceConfig::v80(), 1024, 1024);
        assert!(v.best.wp_int4 >= u.best.wp_int4);
    }
}
