//! Design-space exploration: the paper's ILP parameter tuning.
//!
//! "We tune these parameters via Integer Linear Programming (ILP) under
//! hardware constraints (resources and memory bandwidth) to minimize T_p
//! / T_d" (Sec. IV-B). The objective (Eqs. 4/6) is linear in the
//! reciprocal parallelism variables over a small discrete grid, so exact
//! minimization by enumeration with constraint pruning ("branch and
//! bound" degenerate case) matches the ILP optimum. We implement exactly
//! that: exhaustive search with feasibility pruning, which is both exact
//! and fast (< 1 ms per stage) on the paper's grid sizes.

use crate::anyhow::{anyhow, Result};
use crate::arch::{DecodeArch, DecodeConfig, PrefillArch, PrefillConfig};
use crate::config::{DeviceConfig, ModelDims};
use crate::coordinator::{run_open_loop, OpenLoopConfig, PrefillPolicy, ShardRole,
                         TopologyConfig};
use crate::util::fmt_json_f64;

/// Resource headroom for P&R closure (fraction of each class usable).
pub const HEADROOM: f64 = 0.88;

/// Decode bandwidth oversubscription: Eq. 7 sums the *peak* demand of the
/// INT4 linear engine and both MHA engines, but they alternate within a
/// token (the linear engine stalls during the attention phase), so the
/// sustained demand is lower. The paper's own V80 point (WPint4=4096,
/// WPmha=1024 → 1.23 TB/s peak vs 820 GB/s HBM) is only feasible under
/// this interpretation; 1.6× covers it with margin.
pub const DECODE_BW_OVERSUB: f64 = 1.6;

/// Outcome of a DSE run.
#[derive(Debug, Clone)]
pub struct DseResult<C> {
    pub best: C,
    pub latency_s: f64,
    pub evaluated: usize,
    pub feasible: usize,
    /// (config, latency) Pareto-ish trail for reporting.
    pub trail: Vec<(C, f64)>,
}

/// Candidate grids (multiples the paper's configs live on).
fn tp_grid() -> Vec<u64> {
    vec![2, 4, 8, 16, 32]
}
fn wp_grid() -> Vec<u64> {
    vec![8, 16, 24, 32, 48, 64, 96, 128, 192, 256]
}
fn wide_wp_grid() -> Vec<u64> {
    vec![128, 256, 512, 1024, 2048, 4096, 8192]
}
fn bp_grid() -> Vec<u64> {
    vec![4, 8, 16, 32, 64]
}

/// Tune the prefill architecture for `l_p`-token prompts on `device`.
pub fn tune_prefill(model: &ModelDims, device: &DeviceConfig, l_p: u64) -> DseResult<PrefillConfig> {
    let mut best: Option<(PrefillConfig, f64)> = None;
    let mut evaluated = 0;
    let mut feasible = 0;
    let mut trail = Vec::new();
    for &tp in &tp_grid() {
        for &wp_kqvo in &wp_grid() {
            for &wp_mha in &wp_grid() {
                for &wp_ffn in &wp_grid() {
                    evaluated += 1;
                    let cfg = PrefillConfig { tp, wp_kqvo, wp_mha, wp_ffn };
                    let arch = PrefillArch::new(cfg, model.clone(), device.clone());
                    // constraints: resources fit + Eq. 5 bandwidth under cap
                    if !device.fits(&arch.resources, HEADROOM)
                        || arch.peak_bandwidth() > device.hbm_bw
                    {
                        continue;
                    }
                    feasible += 1;
                    let t = arch.analytic_latency_s(l_p);
                    if best.as_ref().map(|(_, b)| t < *b).unwrap_or(true) {
                        trail.push((cfg, t));
                        best = Some((cfg, t));
                    }
                }
            }
        }
    }
    let (best, latency_s) = best.expect("no feasible prefill configuration");
    DseResult { best, latency_s, evaluated, feasible, trail }
}

/// Tune the decode architecture for a [l_p, l_d] workload on `device`.
pub fn tune_decode(
    model: &ModelDims,
    device: &DeviceConfig,
    l_p: u64,
    l_d: u64,
) -> DseResult<DecodeConfig> {
    let mut best: Option<(DecodeConfig, f64)> = None;
    let mut evaluated = 0;
    let mut feasible = 0;
    let mut trail = Vec::new();
    for &bp in &bp_grid() {
        for &wp_int4 in &wide_wp_grid() {
            if wp_int4 < bp {
                continue;
            }
            for &wp_mha in &wp_grid().iter().copied().chain([512, 1024]).collect::<Vec<_>>() {
                evaluated += 1;
                let cfg = DecodeConfig { bp, wp_int4, wp_mha };
                let arch = DecodeArch::new(cfg, model.clone(), device.clone());
                if !device.fits(&arch.resources, HEADROOM)
                    || arch.peak_bandwidth() > device.hbm_bw * DECODE_BW_OVERSUB
                {
                    continue;
                }
                feasible += 1;
                let t = arch.analytic_latency_s(l_p, l_d);
                if best.as_ref().map(|(_, b)| t < *b).unwrap_or(true) {
                    trail.push((cfg, t));
                    best = Some((cfg, t));
                }
            }
        }
    }
    let (best, latency_s) = best.expect("no feasible decode configuration");
    DseResult { best, latency_s, evaluated, feasible, trail }
}

/// One evaluated topology in a shard-mix search.
#[derive(Debug, Clone)]
pub struct ShardMixPoint {
    pub roles: Vec<ShardRole>,
    /// Compact label, e.g. `"2u"` or `"1p+1d"`.
    pub summary: String,
    /// Whether any shard is a specialist.
    pub mixed: bool,
    pub ttft_p95_s: f64,
    /// Aggregate decode throughput (modeled tokens/s over the makespan).
    pub decode_tps: f64,
    pub migrations: usize,
}

impl ShardMixPoint {
    pub fn to_json(&self) -> String {
        format!(
            "{{\"topology\": \"{}\", \"mixed\": {}, \"ttft_p95_s\": {}, \
             \"decode_tps\": {}, \"migrations\": {}}}",
            self.summary, self.mixed, fmt_json_f64(self.ttft_p95_s),
            fmt_json_f64(self.decode_tps), self.migrations,
        )
    }
}

/// Outcome of [`tune_shard_mix`]: every evaluated topology plus the best
/// mixed and best homogeneous points (indices into `points`).
#[derive(Debug, Clone)]
pub struct ShardMixResult {
    pub points: Vec<ShardMixPoint>,
    pub best_mixed: usize,
    pub best_homogeneous: usize,
}

impl ShardMixResult {
    pub fn best_mixed(&self) -> &ShardMixPoint {
        &self.points[self.best_mixed]
    }

    pub fn best_homogeneous(&self) -> &ShardMixPoint {
        &self.points[self.best_homogeneous]
    }

    pub fn to_json(&self) -> String {
        let points: Vec<String> = self.points.iter().map(|p| p.to_json()).collect();
        format!(
            "{{\"best_mixed\": {}, \"best_homogeneous\": {}, \"points\": [{}]}}",
            self.best_mixed().to_json(), self.best_homogeneous().to_json(),
            points.join(", "),
        )
    }
}

/// `a` dominates-or-beats `b` for the shard-mix objective: maximize
/// aggregate decode throughput, break ties on lower p95 TTFT.
fn mix_better(a: &ShardMixPoint, b: &ShardMixPoint) -> bool {
    a.decode_tps > b.decode_tps
        || (a.decode_tps == b.decode_tps && a.ttft_p95_s < b.ttft_p95_s)
}

/// Shard-mix search: for a given Poisson (or burst) arrival process at
/// EQUAL total KV memory, sweep every topology up to `max_shards` —
/// homogeneous `n`×`Unified` for n in 1..=N, and every disaggregated
/// split `p`×`Prefill` + `(n-p)`×`Decode` — through the open-loop
/// harness, and report the best mixed and best homogeneous points.
///
/// This is the serving-layer analogue of the per-stage ILP above: the
/// per-stage search fixes each engine's parallelism; this one fixes how
/// many engines to specialize per stage. Topologies an uneven budget
/// split refuses (or that park requests forever) are skipped, not
/// fatal — they are simply infeasible points.
pub fn tune_shard_mix(policy: PrefillPolicy, base: &OpenLoopConfig,
                      max_shards: usize) -> Result<ShardMixResult> {
    if max_shards < 2 {
        return Err(anyhow!("shard-mix search needs max_shards >= 2"));
    }
    if base.paged.is_none() {
        return Err(anyhow!(
            "shard-mix search needs a paged pool: migration moves page tables"));
    }
    let mut topologies: Vec<Vec<ShardRole>> = Vec::new();
    for n in 1..=max_shards {
        topologies.push(vec![ShardRole::Unified; n]);
        for p in 1..n {
            let t = TopologyConfig::disaggregated(p, n - p);
            topologies.push(t.roles);
        }
    }
    let mut points = Vec::new();
    for roles in topologies {
        let mut cfg = base.clone();
        cfg.shards = roles.len();
        cfg.roles = roles.clone();
        let Ok(stats) = run_open_loop(policy, &cfg) else {
            continue;
        };
        let topo = TopologyConfig { roles: roles.clone() };
        points.push(ShardMixPoint {
            summary: topo.summary(),
            mixed: topo.disaggregated_any(),
            roles,
            ttft_p95_s: stats.ttft_p95_s,
            decode_tps: stats.throughput_tps(),
            migrations: stats.migrations,
        });
    }
    let pick = |want_mixed: bool| -> Option<usize> {
        let mut best: Option<usize> = None;
        for (i, p) in points.iter().enumerate() {
            if p.mixed == want_mixed
                && best.map(|b| mix_better(p, &points[b])).unwrap_or(true)
            {
                best = Some(i);
            }
        }
        best
    };
    let best_mixed =
        pick(true).ok_or_else(|| anyhow!("no feasible mixed topology"))?;
    let best_homogeneous =
        pick(false).ok_or_else(|| anyhow!("no feasible homogeneous topology"))?;
    Ok(ShardMixResult { points, best_mixed, best_homogeneous })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prefill_dse_finds_near_paper_point() {
        let model = ModelDims::llama32_1b();
        let dev = DeviceConfig::u280();
        let r = tune_prefill(&model, &dev, 1024);
        // the found optimum must be at least as good as the paper's config
        let paper = PrefillArch::new(PrefillConfig::u280_paper(), model.clone(), dev.clone());
        assert!(r.latency_s <= paper.analytic_latency_s(1024) * 1.02,
                "dse {} vs paper {}", r.latency_s, paper.analytic_latency_s(1024));
        assert!(r.feasible > 0 && r.feasible <= r.evaluated);
    }

    #[test]
    fn decode_dse_finds_near_paper_point() {
        let model = ModelDims::llama32_1b();
        let dev = DeviceConfig::u280();
        let r = tune_decode(&model, &dev, 1024, 1024);
        let paper = DecodeArch::new(DecodeConfig::u280_paper(), model.clone(), dev.clone());
        assert!(r.latency_s <= paper.analytic_latency_s(1024, 1024) * 1.02);
    }

    #[test]
    fn dse_respects_bandwidth_constraint() {
        let model = ModelDims::llama32_1b();
        let dev = DeviceConfig::u280();
        let r = tune_decode(&model, &dev, 512, 512);
        let arch = DecodeArch::new(r.best, model, dev.clone());
        assert!(arch.peak_bandwidth() <= dev.hbm_bw * DECODE_BW_OVERSUB);
    }

    #[test]
    fn shard_mix_sweep_covers_all_topologies() {
        use crate::coordinator::{ArrivalProcess, PagedPoolConfig};
        let cfg = OpenLoopConfig {
            requests: 12,
            arrival: ArrivalProcess::Poisson { rate_rps: 8.0 },
            min_new_tokens: 8,
            max_new_tokens: 16,
            paged: Some(PagedPoolConfig::same_memory_as_dense(4, 320, 32, 16)),
            ..OpenLoopConfig::default()
        };
        let r = tune_shard_mix(PrefillPolicy::chunked(32), &cfg, 2).unwrap();
        // 1u, 2u, 1p+1d — every topology up to 2 shards is feasible here
        assert_eq!(r.points.len(), 3);
        assert!(r.best_mixed().mixed);
        assert!(!r.best_homogeneous().mixed);
        assert_eq!(r.best_mixed().summary, "1p+1d");
        assert!(r.best_mixed().migrations > 0,
                "a mixed topology must actually migrate");
        let j = r.to_json();
        assert!(j.contains("\"topology\": \"1p+1d\""));
        assert!(crate::util::Json::parse(&j).is_ok());
        // a dense workload is refused: migration moves page tables
        let mut dense = cfg.clone();
        dense.paged = None;
        assert!(tune_shard_mix(PrefillPolicy::chunked(32), &dense, 2).is_err());
    }

    #[test]
    fn v80_optimum_wider_than_u280() {
        let model = ModelDims::llama32_1b();
        let u = tune_decode(&model, &DeviceConfig::u280(), 1024, 1024);
        let v = tune_decode(&model, &DeviceConfig::v80(), 1024, 1024);
        assert!(v.best.wp_int4 >= u.best.wp_int4);
    }
}
