//! Minimal JSON parser (offline build: no serde in the vendored set).
//!
//! Supports the full JSON grammar the AOT manifest uses: objects, arrays,
//! strings (with escapes), numbers, booleans, null. Strict enough to
//! reject malformed input with a positioned error.

use std::collections::HashMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(HashMap<String, Json>),
}

/// Parse error with byte offset.
#[derive(Debug)]
pub struct JsonError {
    pub offset: usize,
    pub message: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "JSON error at byte {}: {}", self.offset, self.message)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    /// Parse a complete JSON document (trailing whitespace allowed).
    pub fn parse(src: &str) -> Result<Json, JsonError> {
        let mut p = Parser { bytes: src.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing data after document"));
        }
        Ok(v)
    }

    // ---- typed accessors (None on type/shape mismatch) -----------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().and_then(|n| {
            if n >= 0.0 && n.fract() == 0.0 && n <= u64::MAX as f64 {
                Some(n as u64)
            } else {
                None
            }
        })
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().and_then(|n| {
            if n.fract() == 0.0 && (i64::MIN as f64..=i64::MAX as f64).contains(&n) {
                Some(n as i64)
            } else {
                None
            }
        })
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&HashMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn is_null(&self) -> bool {
        matches!(self, Json::Null)
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: impl Into<String>) -> JsonError {
        JsonError { offset: self.pos, message: msg.into() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek()?;
        self.pos += 1;
        Some(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(format!("expected '{}'", b as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => Err(self.err(format!("unexpected '{}'", c as char))),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(format!("expected '{word}'")))
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = HashMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            m.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(m)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(v)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'n') => s.push('\n'),
                    Some(b'r') => s.push('\r'),
                    Some(b't') => s.push('\t'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or_else(|| self.err("bad \\u escape"))?;
                            code = code * 16
                                + (c as char).to_digit(16)
                                    .ok_or_else(|| self.err("bad hex in \\u"))?;
                        }
                        s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x80 => s.push(c as char),
                Some(c) => {
                    // re-decode multibyte UTF-8 from the source
                    let start = self.pos - 1;
                    let len = match c {
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        _ => 4,
                    };
                    let end = (start + len).min(self.bytes.len());
                    if let Ok(chunk) = std::str::from_utf8(&self.bytes[start..end]) {
                        s.push_str(chunk);
                        self.pos = end;
                    } else {
                        return Err(self.err("invalid UTF-8"));
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| self.err("bad number bytes"))?;
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err(format!("bad number '{text}'")))
    }
}

/// Format a float for hand-rolled JSON emission.
///
/// JSON has no literal for `NaN` or `inf`, and `format!("{:.6}")` happily
/// prints both, producing output `Json::parse` rejects. Every float written
/// into a JSON report must go through this helper, which maps non-finite
/// values to `0.0`.
pub fn fmt_json_f64(v: f64) -> String {
    if v.is_finite() {
        format!("{v:.6}")
    } else {
        "0.000000".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("42").unwrap().as_u64(), Some(42));
        assert_eq!(Json::parse("-3.5").unwrap().as_f64(), Some(-3.5));
        assert_eq!(Json::parse("1e3").unwrap().as_f64(), Some(1000.0));
        assert_eq!(Json::parse("true").unwrap().as_bool(), Some(true));
        assert!(Json::parse("null").unwrap().is_null());
        assert_eq!(Json::parse(r#""hi\n""#).unwrap().as_str(), Some("hi\n"));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[2].get("b").unwrap().as_str(), Some("c"));
        assert!(v.get("d").unwrap().is_null());
    }

    #[test]
    fn rejects_malformed() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse(r#"{"a" 1}"#).is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn unicode_escapes() {
        assert_eq!(Json::parse(r#""Aé""#).unwrap().as_str(), Some("Aé"));
    }

    #[test]
    fn utf8_passthrough() {
        assert_eq!(Json::parse(r#""héllo — ×""#).unwrap().as_str(), Some("héllo — ×"));
    }

    #[test]
    fn fmt_json_f64_maps_non_finite_to_zero() {
        assert_eq!(fmt_json_f64(1.5), "1.500000");
        assert_eq!(fmt_json_f64(0.0), "0.000000");
        assert_eq!(fmt_json_f64(f64::NAN), "0.000000");
        assert_eq!(fmt_json_f64(f64::INFINITY), "0.000000");
        assert_eq!(fmt_json_f64(f64::NEG_INFINITY), "0.000000");
        let doc = format!("{{\"x\": {}}}", fmt_json_f64(f64::NAN));
        assert_eq!(Json::parse(&doc).unwrap().get("x").unwrap().as_f64(), Some(0.0));
    }

    #[test]
    fn integer_boundaries() {
        assert_eq!(Json::parse("0").unwrap().as_u64(), Some(0));
        assert_eq!(Json::parse("-1").unwrap().as_u64(), None);
        assert_eq!(Json::parse("-1").unwrap().as_i64(), Some(-1));
        assert_eq!(Json::parse("1.5").unwrap().as_u64(), None);
    }
}
