//! Tiny property-testing helper (offline build: proptest is not in the
//! vendored set). Deterministic xorshift generator + a `forall` driver
//! that reports the failing case and its seed.

/// Deterministic xorshift64* PRNG — reproducible across runs.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Rng { state: seed.max(1) }
    }

    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545F4914F6CDD1D)
    }

    /// Uniform in [lo, hi] inclusive.
    pub fn u64_in(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo <= hi);
        lo + self.next_u64() % (hi - lo + 1)
    }

    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        self.u64_in(lo as u64, hi as u64) as usize
    }

    /// Uniform float in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform float in [lo, hi).
    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    pub fn bool(&mut self) -> bool {
        self.next_u64() & 1 == 1
    }

    /// Pick a random element of a slice.
    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.usize_in(0, xs.len() - 1)]
    }

    /// Vector of random i32 tokens.
    pub fn tokens(&mut self, len: usize, vocab: i32) -> Vec<i32> {
        (0..len).map(|_| self.u64_in(0, vocab as u64 - 1) as i32).collect()
    }
}

/// Run `cases` random cases of a property; panics with the seed and case
/// index on the first failure so it can be replayed.
pub fn forall(name: &str, cases: usize, mut prop: impl FnMut(&mut Rng) -> Result<(), String>) {
    let base_seed = 0x5EED_0000u64;
    for i in 0..cases {
        let seed = base_seed + i as u64;
        let mut rng = Rng::new(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!("property '{name}' failed at case {i} (seed {seed:#x}): {msg}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rng_is_deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn ranges_respected() {
        let mut r = Rng::new(42);
        for _ in 0..1000 {
            let v = r.u64_in(3, 9);
            assert!((3..=9).contains(&v));
            let f = r.f64_in(-2.0, 2.0);
            assert!((-2.0..2.0).contains(&f));
        }
    }

    #[test]
    fn forall_reports_failure() {
        let result = std::panic::catch_unwind(|| {
            forall("always-fails", 3, |_| Err("nope".into()));
        });
        assert!(result.is_err());
    }

    #[test]
    fn forall_passes_good_property() {
        forall("u64_in bounds", 50, |rng| {
            let v = rng.u64_in(1, 6);
            if (1..=6).contains(&v) { Ok(()) } else { Err(format!("{v} out of range")) }
        });
    }
}
