//! In-tree `anyhow` replacement (offline build: the vendored crate set
//! has no external dependencies at all — see Cargo.toml).
//!
//! Provides the narrow slice of the `anyhow` API this crate uses:
//! [`Error`] (a message plus a context chain), [`Result`], the
//! [`Context`] extension trait, and the `anyhow!` / `bail!` macros. The
//! crate root re-exports all of it under a module named `anyhow`, so
//! call sites read identically to the real crate
//! (`use crate::anyhow::{anyhow, Result};`).
//!
//! Semantics match what the call sites rely on: `Display` prints the
//! outermost message, the alternate form (`{:#}`) prints the whole
//! chain outermost-first joined by `": "`, and any `std::error::Error`
//! converts via `?`.

use std::fmt;

/// Error value: innermost message plus contexts added around it.
pub struct Error {
    /// `chain[0]` is the innermost (original) message; later entries
    /// are contexts wrapped around it, outermost last.
    chain: Vec<String>,
}

impl Error {
    /// Build an error from a message (what the `anyhow!` macro calls).
    pub fn msg(msg: impl Into<String>) -> Self {
        Error { chain: vec![msg.into()] }
    }

    /// Wrap the error in an outer context message.
    pub fn context(mut self, ctx: impl Into<String>) -> Self {
        self.chain.push(ctx.into());
        self
    }

    /// The outermost message (what `Display` prints).
    pub fn outermost(&self) -> &str {
        self.chain.last().map(String::as_str).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}`: the whole chain, outermost first
            let mut first = true;
            for msg in self.chain.iter().rev() {
                if !first {
                    write!(f, ": ")?;
                }
                write!(f, "{msg}")?;
                first = false;
            }
            Ok(())
        } else {
            write!(f, "{}", self.outermost())
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.outermost())?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for msg in self.chain.iter().rev().skip(1) {
                write!(f, "\n    {msg}")?;
            }
        }
        Ok(())
    }
}

// NOTE: `Error` deliberately does NOT implement `std::error::Error`;
// that is what keeps this blanket conversion coherent (mirroring the
// real `anyhow`, which needs specialization for the same trick).
impl<E: std::error::Error> From<E> for Error {
    fn from(e: E) -> Self {
        Error::msg(e.to_string())
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// `.context(...)` / `.with_context(|| ...)` on `Result`.
pub trait Context<T> {
    fn context(self, ctx: impl fmt::Display) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context(self, ctx: impl fmt::Display) -> Result<T> {
        self.map_err(|e| e.into().context(ctx.to_string()))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f().to_string()))
    }
}

/// `anyhow!`: build an [`Error`] from a format string (exported at the
/// crate root; also importable as `anyhow::anyhow`).
#[macro_export]
macro_rules! __flexllm_anyhow {
    ($msg:literal $(,)?) => {
        $crate::util::error::Error::msg(format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::util::error::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::util::error::Error::msg(format!("{}", $err))
    };
}

/// `bail!`: early-return an `Err(anyhow!(...))`.
#[macro_export]
macro_rules! __flexllm_bail {
    ($($t:tt)*) => {
        return Err($crate::__flexllm_anyhow!($($t)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing file")
    }

    #[test]
    fn display_shows_outermost_alternate_shows_chain() {
        let e = Error::msg("inner").context("middle").context("outer");
        assert_eq!(e.to_string(), "outer");
        assert_eq!(format!("{e:#}"), "outer: middle: inner");
        let dbg = format!("{e:?}");
        assert!(dbg.contains("outer") && dbg.contains("Caused by"));
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert_eq!(f().unwrap_err().to_string(), "missing file");
    }

    #[test]
    fn context_trait_wraps_results() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.with_context(|| format!("reading {}", "x")).unwrap_err();
        assert_eq!(format!("{e:#}"), "reading x: missing file");
        let r: Result<()> = Err(Error::msg("boom"));
        assert_eq!(r.context("ctx").unwrap_err().to_string(), "ctx");
    }

    #[test]
    fn macros_format_and_bail() {
        let lane = 3;
        let e = crate::anyhow!("lane {lane} out of range");
        assert_eq!(e.to_string(), "lane 3 out of range");
        let e = crate::anyhow!("{} of {}", 1, 2);
        assert_eq!(e.to_string(), "1 of 2");
        fn f() -> Result<()> {
            crate::bail!("nope {}", 7);
        }
        assert_eq!(f().unwrap_err().to_string(), "nope 7");
    }
}
