//! In-tree infrastructure for the offline build (the vendored crate set
//! carries only `xla` + `anyhow`): JSON parsing, a bench harness, and
//! property-testing helpers.

pub mod bench;
pub mod json;
pub mod prop;

pub use json::{Json, JsonError};
