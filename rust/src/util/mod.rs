//! In-tree infrastructure for the offline build (the core crate has NO
//! external dependencies — see Cargo.toml): error handling, JSON
//! parsing, a bench harness, and property-testing helpers.

pub mod bench;
pub mod error;
pub mod json;
pub mod prop;

pub use json::{fmt_json_f64, Json, JsonError};
