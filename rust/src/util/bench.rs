//! Tiny benchmark harness (offline build: criterion is not in the
//! vendored set). Used by the `benches/` targets (`harness = false`).
//!
//! Reports min / mean / p50 / p95 wall time per iteration, with an
//! automatic warm-up and sample-count selection aiming at ~0.5 s per
//! benchmark (overridable).

use std::time::{Duration, Instant};

/// One benchmark result.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub samples: usize,
    pub min: Duration,
    pub mean: Duration,
    pub p50: Duration,
    pub p95: Duration,
}

impl BenchResult {
    pub fn report(&self) {
        println!(
            "{:<44} {:>10} {:>10} {:>10} {:>10}   ({} samples)",
            self.name,
            fmt_dur(self.min),
            fmt_dur(self.mean),
            fmt_dur(self.p50),
            fmt_dur(self.p95),
            self.samples
        );
    }
}

fn fmt_dur(d: Duration) -> String {
    let ns = d.as_nanos();
    if ns < 1_000 {
        format!("{ns} ns")
    } else if ns < 1_000_000 {
        format!("{:.2} µs", ns as f64 / 1e3)
    } else if ns < 1_000_000_000 {
        format!("{:.2} ms", ns as f64 / 1e6)
    } else {
        format!("{:.3} s", ns as f64 / 1e9)
    }
}

/// A bench group with a shared time budget per benchmark.
#[derive(Debug)]
pub struct Bench {
    budget: Duration,
    min_samples: usize,
    max_samples: usize,
    pub results: Vec<BenchResult>,
}

impl Default for Bench {
    fn default() -> Self {
        Self::new()
    }
}

impl Bench {
    pub fn new() -> Self {
        Bench {
            budget: Duration::from_millis(500),
            min_samples: 5,
            max_samples: 200,
            results: Vec::new(),
        }
    }

    /// Lower the sample budget for expensive benchmarks.
    pub fn heavy(mut self) -> Self {
        self.budget = Duration::from_secs(2);
        self.max_samples = 20;
        self
    }

    /// Run one benchmark: `f` is invoked repeatedly, its result black-boxed.
    pub fn run<T>(&mut self, name: &str, mut f: impl FnMut() -> T) -> &BenchResult {
        // warm-up: one untimed call
        std::hint::black_box(f());
        // pilot to estimate per-iter cost
        let t0 = Instant::now();
        std::hint::black_box(f());
        let pilot = t0.elapsed().max(Duration::from_nanos(50));
        let samples = ((self.budget.as_secs_f64() / pilot.as_secs_f64()) as usize)
            .clamp(self.min_samples, self.max_samples);

        let mut times = Vec::with_capacity(samples);
        for _ in 0..samples {
            let t = Instant::now();
            std::hint::black_box(f());
            times.push(t.elapsed());
        }
        times.sort();
        let total: Duration = times.iter().sum();
        let r = BenchResult {
            name: name.to_string(),
            samples,
            min: times[0],
            mean: total / samples as u32,
            p50: times[samples / 2],
            p95: times[(samples as f64 * 0.95) as usize % samples],
        };
        r.report();
        self.results.push(r);
        self.results.last().unwrap()
    }

    /// Print the header row once at the start of a bench binary.
    pub fn header(title: &str) {
        println!("\n### {title}");
        println!(
            "{:<44} {:>10} {:>10} {:>10} {:>10}",
            "benchmark", "min", "mean", "p50", "p95"
        );
        println!("{}", "-".repeat(92));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_and_records() {
        let mut b = Bench::new();
        let r = b.run("noop", || 1 + 1);
        assert!(r.samples >= 5);
        assert!(r.min <= r.mean);
        assert_eq!(b.results.len(), 1);
    }

    #[test]
    fn duration_formatting() {
        assert!(fmt_dur(Duration::from_nanos(500)).contains("ns"));
        assert!(fmt_dur(Duration::from_micros(50)).contains("µs"));
        assert!(fmt_dur(Duration::from_millis(50)).contains("ms"));
        assert!(fmt_dur(Duration::from_secs(2)).contains(" s"));
    }
}
