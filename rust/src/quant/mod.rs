//! Quantization scheme descriptors (Rust mirror of
//! `python/compile/quantize.py::SCHEMES`, paper Table V).
//!
//! The L3 side needs schemes for two things: sizing the datapaths of
//! composed architectures (bytes per weight at each site → bandwidth and
//! HBM capacity) and labelling the ablation harness. The actual
//! quantization *numerics* live in the L1 kernels.


use crate::config::Precision;

/// How attention (QKᵀ/PV + KV cache) is quantized.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AttnMode {
    /// Full precision (No_Quant).
    Fp,
    /// FP query path + dynamic INT4 KV (original SpinQuant setup, Q0).
    FpKv4,
    /// Dynamic symmetric INT8 (Q1).
    Dyn8,
    /// Static symmetric INT8 (Q2/Q3) — the hardware-friendly final form.
    Sta8,
}

impl AttnMode {
    pub fn kv_precision(self) -> Precision {
        match self {
            AttnMode::Fp => Precision::Fp16,
            AttnMode::FpKv4 => Precision::Int4,
            AttnMode::Dyn8 | AttnMode::Sta8 => Precision::Int8,
        }
    }

    pub fn label(self) -> &'static str {
        match self {
            AttnMode::Fp => "BF16",
            AttnMode::FpKv4 => "BF16-INT4",
            AttnMode::Dyn8 => "Dyn. INT8",
            AttnMode::Sta8 => "Sta. INT8",
        }
    }
}

/// One row of Table V.
#[derive(Debug, Clone)]
pub struct Scheme {
    pub name: &'static str,
    pub display: &'static str,
    pub linear_w: Precision,
    pub linear_a: Precision,
    pub attn: AttnMode,
    pub lm_head: Precision,
    /// Paper-reported WikiText-2 perplexity for Llama-3.2 1B.
    pub paper_ppl: f64,
}

impl Scheme {
    pub fn no_quant() -> Self {
        Scheme { name: "noquant", display: "No_Quant", linear_w: Precision::Fp16,
                 linear_a: Precision::Fp16, attn: AttnMode::Fp,
                 lm_head: Precision::Fp16, paper_ppl: 8.94 }
    }

    pub fn q0() -> Self {
        Scheme { name: "q0", display: "Q0 (SpinQuant)", linear_w: Precision::Int4,
                 linear_a: Precision::Int4, attn: AttnMode::FpKv4,
                 lm_head: Precision::Fp16, paper_ppl: 13.30 }
    }

    pub fn q1() -> Self {
        Scheme { name: "q1", display: "Q1", linear_w: Precision::Int4,
                 linear_a: Precision::Int4, attn: AttnMode::Dyn8,
                 lm_head: Precision::Fp16, paper_ppl: 12.07 }
    }

    pub fn q2() -> Self {
        Scheme { name: "q2", display: "Q2", linear_w: Precision::Int4,
                 linear_a: Precision::Int4, attn: AttnMode::Sta8,
                 lm_head: Precision::Fp16, paper_ppl: 12.28 }
    }

    /// The deployed W4A4KV8 scheme.
    pub fn q3() -> Self {
        Scheme { name: "q3", display: "Q3 (Final)", linear_w: Precision::Int4,
                 linear_a: Precision::Int4, attn: AttnMode::Sta8,
                 lm_head: Precision::Int4, paper_ppl: 12.68 }
    }

    pub fn all() -> Vec<Scheme> {
        vec![Self::no_quant(), Self::q0(), Self::q1(), Self::q2(), Self::q3()]
    }

    /// Allo baseline scheme (W4A8KV8 SmoothQuant, Sec. VI-A).
    pub fn allo_w4a8() -> Self {
        Scheme { name: "allo_w4a8", display: "Allo W4A8KV8", linear_w: Precision::Int4,
                 linear_a: Precision::Int8, attn: AttnMode::Sta8,
                 lm_head: Precision::Fp16, paper_ppl: f64::NAN }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_v_rows() {
        let all = Scheme::all();
        assert_eq!(all.len(), 5);
        assert_eq!(Scheme::q3().paper_ppl, 12.68);
        assert_eq!(Scheme::q0().attn.kv_precision(), Precision::Int4);
        assert_eq!(Scheme::q3().attn.kv_precision(), Precision::Int8);
        assert_eq!(Scheme::q3().lm_head, Precision::Int4);
        assert_eq!(Scheme::q2().lm_head, Precision::Fp16);
    }

    #[test]
    fn paper_ordering() {
        // No_Quant < Q1 < Q2 < Q3 < Q0 on WikiText-2
        let (nq, q0, q1, q2, q3) = (Scheme::no_quant().paper_ppl, Scheme::q0().paper_ppl,
                                    Scheme::q1().paper_ppl, Scheme::q2().paper_ppl,
                                    Scheme::q3().paper_ppl);
        assert!(nq < q1 && q1 < q2 && q2 < q3 && q3 < q0);
    }
}
