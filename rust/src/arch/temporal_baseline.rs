//! Temporal-architecture baseline (FlightLLM-like, Fig. 1(b)(c)).
//!
//! One monolithic compute engine is reused for every kernel in every
//! layer. Utilization of the engine itself is high, but (a) nothing
//! overlaps — every kernel is serialized through the shared engine — and
//! (b) limited on-chip buffering forces intermediate activations off-chip
//! in prefill, adding HBM round-trips the spatial/hybrid designs stream
//! through FIFOs.

use crate::config::{DeviceConfig, ModelDims, Precision};
use crate::hls::{achieved_frequency, Resources};
use crate::hls::calibration as cal;

/// A FlightLLM-style monolithic engine sized to a device.
#[derive(Debug)]
pub struct TemporalBaseline {
    pub model: ModelDims,
    pub device: DeviceConfig,
    /// MACs per cycle of the shared engine (its only parallelism knob).
    pub engine_macs: u64,
    pub freq_hz: f64,
    pub resources: Resources,
}

impl TemporalBaseline {
    /// Size the engine to roughly the same fabric budget as the hybrid
    /// design (fair comparison: equal resources, different organization).
    pub fn new(model: ModelDims, device: DeviceConfig, engine_macs: u64) -> Self {
        let pe = cal::pe_cost(Precision::Int8); // monolithic engines run one precision
        let resources = (pe * engine_macs as f64
            + cal::platform_overhead()
            + cal::weight_stream_buffers(engine_macs.min(2048), Precision::Int8))
            .with_derived_clb();
        let util = device.utilization(&resources).max_class();
        let freq_hz = achieved_frequency(&device, util, engine_macs.min(2048));
        TemporalBaseline { model, device, engine_macs, freq_hz, resources }
    }

    pub fn u280() -> Self {
        Self::new(ModelDims::llama32_1b(), DeviceConfig::u280(), 4096)
    }

    /// Effective compute utilization of the monolithic engine: every
    /// kernel switch drains/refills the rigid array and differently-shaped
    /// ops (attention vs FFN vs projections) cannot all map efficiently —
    /// the Fig. 1(b,c) pathology. FlightLLM-class designs report well
    /// under half of peak on mixed prefill kernels.
    const PREFILL_ENGINE_UTIL: f64 = 0.42;
    /// Effective HBM utilization in decode: activation spill/refill and
    /// weight re-fetch compete on the same channels ("frequent off-chip
    /// memory access", Fig. 1(c)).
    const DECODE_BW_UTIL: f64 = 0.35;

    /// Prefill: all kernels serialized through the engine + activation
    /// spill/refill traffic per layer (limited buffering).
    pub fn prefill_latency_s(&self, l_p: u64) -> f64 {
        let m = &self.model;
        let macs = m.flops_per_token() / 2.0 * l_p as f64
            + (m.n_layers * m.d_model * l_p * l_p) as f64; // attention
        let compute_cycles = macs / (self.engine_macs as f64 * Self::PREFILL_ENGINE_UTIL);
        // activation spills: 2 round trips of [l_p, d] per layer at INT8
        let spill_bytes = (2 * m.n_layers * l_p * m.d_model) as f64 * 2.0;
        let spill_s = spill_bytes / self.device.hbm_bw * 4.0; // effective BW ~25%
        compute_cycles / self.freq_hz + spill_s
    }

    /// Decode: same engine, weights at INT8 (FlightLLM-class precision),
    /// fully serialized; bandwidth-bound on weight streaming.
    pub fn decode_latency_s(&self, l_p: u64, l_d: u64) -> f64 {
        let m = &self.model;
        let avg_ctx = l_p as f64 + 0.5 * l_d as f64;
        let weight_bytes = m.decode_weight_bytes(1.0, 1.0); // INT8
        let kv_bytes = m.kv_bytes_per_token(avg_ctx as u64, 1.0);
        let bw_s = (weight_bytes + kv_bytes) / (self.device.hbm_bw * Self::DECODE_BW_UTIL);
        let compute_s =
            (m.flops_per_token() / 2.0) / self.engine_macs as f64 / self.freq_hz;
        l_d as f64 * bw_s.max(compute_s) * 1.15
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::{DecodeArch, DecodeConfig, PrefillArch, PrefillConfig};

    #[test]
    fn hybrid_beats_temporal_prefill() {
        // Fig. 1's argument: the stage-customized hybrid outperforms the
        // monolithic temporal engine on prefill (streaming + no spills).
        let t = TemporalBaseline::u280();
        let h = PrefillArch::new(PrefillConfig::u280_paper(), ModelDims::llama32_1b(),
                                 DeviceConfig::u280());
        assert!(h.analytic_latency_s(1024) < t.prefill_latency_s(1024));
    }

    #[test]
    fn hybrid_beats_temporal_decode() {
        let t = TemporalBaseline::u280();
        let h = DecodeArch::new(DecodeConfig::u280_paper(), ModelDims::llama32_1b(),
                                DeviceConfig::u280());
        assert!(h.analytic_latency_s(1024, 1024) < t.decode_latency_s(1024, 1024));
    }

    #[test]
    fn temporal_fits_device() {
        let t = TemporalBaseline::u280();
        assert!(t.device.utilization(&t.resources).max_class() < 0.95);
    }
}
