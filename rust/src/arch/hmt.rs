//! HMT plug-in architecture (paper Fig. 5(c), Case Study 2).
//!
//! The Hierarchical Memory Transformer plug-in reuses the FlexLLM linear
//! and attention templates to implement segment summarization, memory
//! generation and history retrieval. Long prompts are split into
//! segments; each segment costs one short backbone prefill plus one
//! memory cross-attention, converting quadratic prompt processing into
//! linear.

use std::sync::Arc;

use crate::config::{DeviceConfig, ModelDims, Precision};
use crate::hls::{
    DataflowGraph, DecodeLinear, KvCache, MhaEngine, NonLinear, NonLinearKind, Resources,
    StreamEdge,
};

/// HMT plug-in knobs (Table VI rows 4/7).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct HmtConfig {
    /// Memory-queue length N (recent segment embeddings retained).
    pub n_memories: u64,
    /// Block parallelism of the plug-in's datapaths.
    pub bp: u64,
    /// Weight parallelism of the memory-attention engine.
    pub wp_mem_attn: u64,
    /// Segment length in tokens.
    pub segment_len: u64,
}

impl HmtConfig {
    /// The paper's U280 plug-in configuration.
    pub fn u280_paper() -> Self {
        HmtConfig { n_memories: 64, bp: 4, wp_mem_attn: 4, segment_len: 512 }
    }

    /// The paper's V80 plug-in configuration.
    pub fn v80_paper() -> Self {
        HmtConfig { n_memories: 64, bp: 4, wp_mem_attn: 8, segment_len: 512 }
    }
}

/// A composed HMT plug-in attached to a backbone accelerator.
#[derive(Debug, Clone)]
pub struct HmtPlugin {
    pub cfg: HmtConfig,
    pub model: ModelDims,
    pub device: DeviceConfig,
    pub resources: Resources,
}

impl HmtPlugin {
    pub fn new(cfg: HmtConfig, model: ModelDims, device: DeviceConfig) -> Self {
        let resources = build_graph(&cfg, &model).resources().with_derived_clb();
        HmtPlugin { cfg, model, device, resources }
    }

    /// Plug-in cycles per segment: summary projection, cross-attention
    /// over the memory queue, retrieved-embedding projection, and the
    /// memory-queue update.
    pub fn plugin_cycles_per_segment(&self) -> f64 {
        let d = self.model.d_model as f64;
        let n = self.cfg.n_memories as f64;
        let wp = self.cfg.wp_mem_attn as f64;
        // q-proj (d²) + k/v proj of the new memory (2·d·d_kv) + out-proj (d²)
        let linear = (2.0 * d * d + 2.0 * d * self.model.d_kv as f64) / wp;
        // cross-attention over N memories (QKᵀ + PV)
        let attn = 2.0 * n * d / wp;
        // queue shift + embedding write
        let queue = n + d;
        linear + attn + queue
    }

    /// Wall-clock per segment at the backbone's achieved frequency.
    pub fn seconds_per_segment(&self, freq_hz: f64) -> f64 {
        self.plugin_cycles_per_segment() / freq_hz
    }

    /// Fraction of the device consumed by the plug-in (paper: <7.5% on
    /// U280, <3.8% on V80).
    pub fn utilization(&self) -> Resources {
        self.device.utilization(&self.resources)
    }

    /// Context-window extension factor (paper: >64× on U280).
    ///
    /// The backbone attends over one segment at a time; the memory queue
    /// extends recall to `n_memories` summarized segments, so the
    /// effective window grows from `segment_len` to
    /// `n_memories × segment_len` — a factor of `n_memories` (64 with the
    /// paper's queue), independent of HBM capacity.
    pub fn context_extension(&self) -> f64 {
        self.cfg.n_memories as f64
    }

    /// Resident KV bytes with HMT active: one segment of cache plus the
    /// FP16 memory queue (vs the full-context cache without HMT).
    pub fn resident_kv_bytes(&self) -> f64 {
        let m = &self.model;
        let seg_kv = (2 * m.n_layers * m.d_kv * self.cfg.segment_len) as f64
            * Precision::Int8.bytes();
        let queue = (self.cfg.n_memories * m.d_model) as f64 * Precision::Fp16.bytes();
        seg_kv + queue
    }

    pub fn graph(&self) -> DataflowGraph {
        build_graph(&self.cfg, &self.model)
    }
}

/// HMT-enhanced prefill: process a `total_ctx` prompt as segments through
/// a backbone whose per-segment prefill latency is given by the closure.
pub fn hmt_prefill_latency_s(
    plugin: &HmtPlugin,
    backbone_prefill_s: impl Fn(u64) -> f64,
    backbone_freq_hz: f64,
    total_ctx: u64,
) -> f64 {
    let seg = plugin.cfg.segment_len;
    let n_segments = total_ctx.div_ceil(seg).max(1);
    // each segment: summary prompt (half segment + topic token) +
    // augmented prompt (full segment + retrieved + short-term slice)
    let summary = backbone_prefill_s(seg / 2 + 1);
    let augmented = backbone_prefill_s(seg + 2);
    let plug = plugin.seconds_per_segment(backbone_freq_hz);
    n_segments as f64 * (summary + augmented + plug)
}

fn build_graph(cfg: &HmtConfig, m: &ModelDims) -> DataflowGraph {
    let mut g = DataflowGraph::new();
    // reuses Linear / MHA / KV_cache templates (paper Table IV row 3)
    let lin = g.invoke_reused(
        Arc::new(DecodeLinear::new("hmt_linear", cfg.bp, cfg.wp_mem_attn,
                                   m.d_model, m.d_model, Precision::Fp16)),
        3.0, 1);
    let attn = g.invoke(Arc::new(MhaEngine::decode(
        "hmt_mem_attn", cfg.wp_mem_attn, m.d_model, m.d_kv, cfg.n_memories, 1)));
    let queue = g.invoke(Arc::new(KvCache::new("hmt_mem_queue", m.d_model, Precision::Fp16)));
    let norm = g.invoke(Arc::new(NonLinear::new("hmt_norm", NonLinearKind::RmsNorm,
                                                cfg.bp, m.d_model)));
    let s = || StreamEdge::activation(cfg.bp);
    g.connect(norm, lin, s());
    g.connect(lin, attn, s());
    g.connect(attn, queue, s());
    g
}

#[cfg(test)]
mod tests {
    use super::*;

    fn u280_plugin() -> HmtPlugin {
        HmtPlugin::new(HmtConfig::u280_paper(), ModelDims::llama32_1b(),
                       DeviceConfig::u280())
    }

    #[test]
    fn table_vi_segment_latency() {
        // Paper: 8.44 ms per segment at 290 MHz on U280. Accept ±25%.
        let p = u280_plugin();
        let t = p.seconds_per_segment(290e6) * 1e3;
        assert!(t > 8.44 * 0.75 && t < 8.44 * 1.25, "ms/segment = {t}");
    }

    #[test]
    fn plugin_resource_overhead_small() {
        // Paper: < 7.5% of total resources on U280.
        let p = u280_plugin();
        let u = p.utilization();
        assert!(u.max_class() < 0.10, "plugin util = {}", u.max_class());
    }

    #[test]
    fn v80_plugin_faster_and_smaller() {
        let u = u280_plugin();
        let v = HmtPlugin::new(HmtConfig::v80_paper(), ModelDims::llama32_1b(),
                               DeviceConfig::v80());
        assert!(v.seconds_per_segment(300e6) < u.seconds_per_segment(290e6));
        assert!(v.utilization().max_class() < u.utilization().max_class());
    }

    #[test]
    fn hmt_prefill_linear_in_context() {
        // doubling the context ~doubles HMT prefill (linear), unlike the
        // quadratic full-attention prefill
        let p = u280_plugin();
        let backbone = |tokens: u64| tokens as f64 * 1.6e-3; // 1.6 ms/token
        let t32k = hmt_prefill_latency_s(&p, backbone, 290e6, 32_768);
        let t64k = hmt_prefill_latency_s(&p, backbone, 290e6, 65_536);
        let ratio = t64k / t32k;
        assert!(ratio > 1.9 && ratio < 2.1, "ratio = {ratio}");
    }
}
