//! Spatial-dataflow baseline (Allo-like, Fig. 1(d)(e); W4A8KV8 per the
//! paper's SOTA-accelerator comparison in Sec. VI-A).
//!
//! A *unified* spatial design: every kernel gets a dedicated module and
//! the same pipeline serves both prefill and decode. It streams well in
//! prefill, but in decode the autoregressive dependency leaves the
//! per-kernel modules idle most of the time (pipeline stalls), and the
//! unified sizing can't shift resources toward the decode bottleneck —
//! exactly the gap stage-customization closes (paper: FlexLLM surpasses
//! Allo by 1.46× E2E / 1.35× decode throughput / 1.10× tokens-per-J).

use std::sync::Arc;

use crate::config::{DeviceConfig, ModelDims, Precision};
use crate::hls::{
    achieved_frequency, simulate, DataflowGraph, Dependency, MhaEngine, NonLinear,
    NonLinearKind, PrefillLinear, Quantizer, Resources, StreamEdge,
};

/// Unified spatial design: one TP/WP point serves both stages.
#[derive(Debug)]
pub struct SpatialBaseline {
    pub model: ModelDims,
    pub device: DeviceConfig,
    /// Inter-token parallelism of the unified pipeline (prefill-oriented).
    pub tp: u64,
    /// Per-kernel weight parallelism of the dedicated modules.
    pub wp: u64,
    pub freq_hz: f64,
    pub resources: Resources,
}

impl SpatialBaseline {
    pub fn new(model: ModelDims, device: DeviceConfig, tp: u64, wp: u64) -> Self {
        let graph = build_graph(&model, tp, wp, 1024);
        let resources = (graph.resources() + crate::hls::calibration::platform_overhead())
            .with_derived_clb();
        let util = device.utilization(&resources).max_class();
        let freq_hz = achieved_frequency(&device, util, wp);
        SpatialBaseline { model, device, tp, wp, freq_hz, resources }
    }

    /// Allo-like W4A8KV8 design sized for U280 (resource-comparable to
    /// the FlexLLM hybrid).
    pub fn u280_allo() -> Self {
        Self::new(ModelDims::llama32_1b(), DeviceConfig::u280(), 8, 56)
    }

    /// Prefill streams well: throughput ≈ slowest dedicated stage.
    pub fn prefill_latency_s(&self, l_p: u64) -> f64 {
        let g = build_graph(&self.model, self.tp, self.wp, l_p);
        let r = simulate(&g, l_p, &[]);
        r.makespan_cycles * self.model.n_layers as f64 / self.freq_hz
    }

    /// Decode suffers the recurrence: simulate with lag-1 dependency from
    /// pipeline tail to head. TP > 1 lanes are idle (single token).
    pub fn decode_latency_s(&self, l_p: u64, l_d: u64) -> f64 {
        let avg_ctx = l_p + l_d / 2;
        let g = build_graph(&self.model, 1, self.wp, avg_ctx);
        let last = g.nodes.len() - 1;
        let dep = Dependency { from: last, to: 0, lag: 1 };
        let r = simulate(&g, l_d.max(2), &[dep]);
        r.makespan_cycles * self.model.n_layers as f64 / self.freq_hz
    }

    /// Decode-stage utilization (the Fig. 1(e) stall story, measurable).
    pub fn decode_utilization(&self, l_p: u64, l_d: u64) -> f64 {
        let avg_ctx = l_p + l_d / 2;
        let g = build_graph(&self.model, 1, self.wp, avg_ctx);
        let last = g.nodes.len() - 1;
        let dep = Dependency { from: last, to: 0, lag: 1 };
        simulate(&g, l_d.max(2), &[dep]).mean_utilization
    }
}

/// Allo-deployment baseline for Fig. 7 (the paper's SOTA accelerator
/// comparison, Sec. VI-A: Allo with W4A8KV8 SmoothQuant on U280).
///
/// Allo's published U280 LLM design is itself engine-reused, so the fair
/// model is the same hybrid composition **without FlexLLM's
/// stage-customized refinements**: INT8 activations mean every linear PE
/// costs an INT8 MAC (0.55 DSP vs 0.42 LUT-heavy INT4), so under the
/// same fabric budget every engine is narrower by that ratio, and the
/// static-SmoothQuant pipeline lacks the dynamic-quant/FHT datapath that
/// lets FlexLLM hold INT4 activations. Net effect: engine widths scale
/// by ≈3/4 in both stages — which the paper measures as 1.46× E2E /
/// 1.35× decode / 1.10× energy in FlexLLM's favor.
#[derive(Debug)]
pub struct AlloBaseline {
    pub prefill: crate::arch::PrefillArch,
    pub decode: crate::arch::DecodeArch,
}

impl AlloBaseline {
    pub fn u280() -> Self {
        let model = ModelDims::llama32_1b();
        // FlexLLM's paper configs scaled by the INT8/INT4 PE-cost ratio
        let pcfg = crate::arch::PrefillConfig { tp: 8, wp_kqvo: 18, wp_mha: 12, wp_ffn: 72 };
        let dcfg = crate::arch::DecodeConfig { bp: 16, wp_int4: 768, wp_mha: 192 };
        AlloBaseline {
            prefill: crate::arch::PrefillArch::new(pcfg, model.clone(), DeviceConfig::u280()),
            decode: crate::arch::DecodeArch::new(dcfg, model, DeviceConfig::u280()),
        }
    }

    pub fn prefill_latency_s(&self, l_p: u64) -> f64 {
        self.prefill.analytic_latency_s(l_p)
    }

    pub fn decode_latency_s(&self, l_p: u64, l_d: u64) -> f64 {
        self.decode.analytic_latency_s(l_p, l_d)
    }

    pub fn e2e_latency_s(&self, l_p: u64, l_d: u64) -> f64 {
        self.prefill_latency_s(l_p) + 0.3 + self.decode_latency_s(l_p, l_d)
    }
}

/// Stage-customization ablation: the FlexLLM **prefill** architecture
/// forced to serve decode too (one unified configuration). One token
/// flows through the prefill engines, so TP−1 lanes idle and the
/// FFN-sized engines must also carry the lm_head — this quantifies what
/// the paper's stage customization is worth on its own.
#[derive(Debug)]
pub struct UnifiedAlloBaseline {
    pub prefill: crate::arch::PrefillArch,
}

impl UnifiedAlloBaseline {
    pub fn u280() -> Self {
        UnifiedAlloBaseline {
            prefill: crate::arch::PrefillArch::new(
                crate::arch::PrefillConfig::u280_paper(),
                ModelDims::llama32_1b(),
                DeviceConfig::u280(),
            ),
        }
    }

    /// Prefill matches the hybrid design (this stage is what the unified
    /// point was sized for).
    pub fn prefill_latency_s(&self, l_p: u64) -> f64 {
        self.prefill.analytic_latency_s(l_p)
    }

    /// Decode on the unified prefill engines, single token (TP lanes
    /// idle), serialized kernel chain per layer + lm_head on the FFN
    /// engine. W4A8KV8 per the paper's Allo setup.
    pub fn decode_latency_s(&self, l_p: u64, l_d: u64) -> f64 {
        let m = &self.prefill.model;
        let c = &self.prefill.cfg;
        let d = m.d_model as f64;
        let avg_ctx = l_p as f64 + 0.5 * l_d as f64;
        let per_layer =
            d * m.d_kv as f64 / c.wp_kqvo as f64            // K (V parallel)
            + d * d / c.wp_kqvo as f64                       // Q
            + 2.0 * d * avg_ctx / c.wp_mha as f64            // QKᵀ + PV
            + d * d / c.wp_kqvo as f64                       // O
            + 2.0 * d * m.d_ffn as f64 / c.wp_ffn as f64;    // gate/up ∥, then down
        let lm_head = d * m.vocab as f64 / c.wp_ffn as f64;
        let cycles = l_d as f64 * (m.n_layers as f64 * per_layer + lm_head);
        cycles / self.prefill.freq_hz
            * crate::hls::calibration::MEASURED_OVERHEAD_DECODE
    }

    pub fn e2e_latency_s(&self, l_p: u64, l_d: u64) -> f64 {
        self.prefill_latency_s(l_p) + self.decode_latency_s(l_p, l_d)
    }
}

/// Unified per-layer pipeline: a dedicated module per kernel (no reuse —
/// the defining property of the fully spatial style).
fn build_graph(m: &ModelDims, tp: u64, wp: u64, ctx: u64) -> DataflowGraph {
    let mut g = DataflowGraph::new();
    let d = m.d_model;
    let mk = |label: &str, d_in: u64, d_out: u64| {
        Arc::new(PrefillLinear::new(label, tp, wp, d_in, d_out, Precision::Int4))
    };
    let quant = g.invoke(Arc::new(Quantizer::new("allo_quant_int8", false, true, false,
                                                 tp, d, 8)));
    let q = g.invoke(mk("allo_linear_q", d, d));
    let k = g.invoke(mk("allo_linear_k", d, m.d_kv));
    let v = g.invoke(mk("allo_linear_v", d, m.d_kv));
    let rope = g.invoke(Arc::new(NonLinear::new("allo_rope", NonLinearKind::RoPE, tp, d)));
    let qk = g.invoke(Arc::new(MhaEngine::prefill("allo_mha_qk", tp, wp, d, m.d_kv,
                                                  ctx, m.n_heads)));
    let sm = g.invoke(Arc::new(NonLinear::new("allo_softmax", NonLinearKind::Softmax,
                                              tp, ctx.max(1))));
    let pv = g.invoke(Arc::new(MhaEngine::prefill("allo_mha_pv", tp, wp, d, m.d_kv,
                                                  ctx, m.n_heads)));
    let o = g.invoke(mk("allo_linear_o", d, d));
    let norm = g.invoke(Arc::new(NonLinear::new("allo_rmsnorm", NonLinearKind::RmsNorm,
                                                tp, d)));
    let gate = g.invoke(mk("allo_linear_gate", d, m.d_ffn));
    let up = g.invoke(mk("allo_linear_up", d, m.d_ffn));
    let swish = g.invoke(Arc::new(NonLinear::new("allo_swish", NonLinearKind::Swish,
                                                 tp, m.d_ffn)));
    let down = g.invoke(mk("allo_linear_down", m.d_ffn, d));

    let s = || StreamEdge::activation(tp);
    g.connect(quant, q, s());
    g.connect(q, k, s());
    g.connect(k, v, s());
    g.connect(v, rope, s());
    g.connect(rope, qk, s());
    g.connect(qk, sm, s());
    g.connect(sm, pv, s());
    g.connect(pv, o, s());
    g.connect(o, norm, s());
    g.connect(norm, gate, s());
    g.connect(gate, up, s());
    g.connect(up, swish, s());
    g.connect(swish, down, s());
    g
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::{DecodeArch, DecodeConfig};

    #[test]
    fn spatial_fits_u280() {
        let a = SpatialBaseline::u280_allo();
        let u = a.device.utilization(&a.resources).max_class();
        assert!(u < 0.92, "util = {u}");
    }

    #[test]
    fn decode_stalls_dominate_spatial() {
        // the defining pathology: unified spatial decode runs well below
        // 50% utilization under the autoregressive recurrence
        let a = SpatialBaseline::u280_allo();
        let u = a.decode_utilization(1024, 64);
        assert!(u < 0.5, "spatial decode util = {u}");
    }

    #[test]
    fn stage_customized_beats_spatial_decode() {
        // paper: 1.35× decode throughput over Allo
        let allo = SpatialBaseline::u280_allo();
        let flex = DecodeArch::new(DecodeConfig::u280_paper(), ModelDims::llama32_1b(),
                                   DeviceConfig::u280());
        let t_allo = allo.decode_latency_s(1024, 256);
        let t_flex = flex.analytic_latency_s(1024, 256);
        let speedup = t_allo / t_flex;
        assert!(speedup > 1.1, "speedup over Allo = {speedup}");
    }
}
