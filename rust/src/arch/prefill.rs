//! Stage-customized **prefill** architecture (paper Fig. 5(a), Eq. 4/5).
//!
//! Hybrid composition: K/V are computed first and stored to HBM; the
//! remaining kernels run as a streaming dataflow pipeline across token
//! tiles, with Q/K sharing one linear+RoPE instance and V/O sharing
//! another (selective temporal reuse inside a spatial pipeline).

use std::sync::Arc;

use crate::config::{DeviceConfig, ModelDims, Precision};
use crate::hls::calibration::MEASURED_OVERHEAD_PREFILL;
use crate::hls::{
    achieved_frequency, simulate, simulate_recurrent, DataflowGraph, Dequantizer, FhtModule,
    KvCache, MhaEngine, NonLinear, NonLinearKind, PrefillLinear, Quantizer, Resources,
    SimResult, StreamEdge,
};

/// The tunable knobs of the prefill architecture (Table VI rows 2/5).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PrefillConfig {
    pub tp: u64,
    pub wp_kqvo: u64,
    pub wp_mha: u64,
    pub wp_ffn: u64,
}

impl PrefillConfig {
    /// The paper's U280 configuration.
    pub fn u280_paper() -> Self {
        PrefillConfig { tp: 8, wp_kqvo: 24, wp_mha: 16, wp_ffn: 96 }
    }

    /// The paper's V80 configuration.
    pub fn v80_paper() -> Self {
        PrefillConfig { tp: 16, wp_kqvo: 32, wp_mha: 32, wp_ffn: 128 }
    }
}

/// A composed prefill accelerator instance on a device.
#[derive(Debug, Clone)]
pub struct PrefillArch {
    pub cfg: PrefillConfig,
    pub model: ModelDims,
    pub device: DeviceConfig,
    pub resources: Resources,
    pub freq_hz: f64,
}

impl PrefillArch {
    pub fn new(cfg: PrefillConfig, model: ModelDims, device: DeviceConfig) -> Self {
        // resources are context-independent; use a nominal ctx for sizing
        let graph = build_graph(&cfg, &model, 1024);
        let resources = (graph.resources() + crate::hls::calibration::platform_overhead())
            .with_derived_clb();
        let util = device.utilization(&resources).max_class();
        let widest = cfg.wp_ffn.max(cfg.wp_kqvo).max(cfg.wp_mha);
        let freq_hz = achieved_frequency(&device, util, widest);
        PrefillArch { cfg, model, device, resources, freq_hz }
    }

    /// Eq. 4 closed-form prefill latency bound, seconds.
    pub fn analytic_latency_s(&self, l_p: u64) -> f64 {
        let m = &self.model;
        let c = &self.cfg;
        let d = m.d_model as f64;
        let per_tile = d * m.d_kv as f64 / c.wp_kqvo as f64
            + (d * d / c.wp_kqvo as f64)
                .max(d * l_p as f64 / c.wp_mha as f64)
                .max(d * m.d_ffn as f64 / c.wp_ffn as f64);
        let cycles = m.n_layers as f64 * l_p as f64 / c.tp as f64 * per_tile
            // final-token lm_head on the FFN engine
            + d * m.vocab as f64 / c.wp_ffn as f64;
        cycles / self.freq_hz * MEASURED_OVERHEAD_PREFILL
    }

    /// Eq. 5 peak bandwidth demand, bytes/second.
    pub fn peak_bandwidth(&self) -> f64 {
        let c = &self.cfg;
        self.freq_hz
            * (Precision::Int4.bytes() * (2 * c.wp_kqvo + 3 * c.wp_ffn) as f64
                + Precision::Int8.bytes() * 2.0 * c.wp_mha as f64)
    }

    /// Stall-aware latency from the dataflow simulator, seconds.
    pub fn simulated_latency_s(&self, l_p: u64) -> f64 {
        self.simulated_chunk_latency_s(l_p, l_p, true)
    }

    /// Stall-aware latency of streaming `tokens` prompt tokens through
    /// the pipeline with the attention engines sized for context `ctx`
    /// (the chunk's end position), seconds. `with_lm_head` adds the
    /// final-token lm_head pass on the FFN engine — only the chunk that
    /// completes a prompt samples a token, so intermediate chunks skip
    /// it. `simulated_latency_s(l_p)` is the whole-prompt special case.
    pub fn simulated_chunk_latency_s(&self, tokens: u64, ctx: u64, with_lm_head: bool)
        -> f64
    {
        let graph = build_graph(&self.cfg, &self.model, ctx.max(1));
        let r = simulate(&graph, tokens.max(1), &[]);
        let lm_head = if with_lm_head {
            self.model.d_model as f64 * self.model.vocab as f64 / self.cfg.wp_ffn as f64
        } else {
            0.0
        };
        (r.makespan_cycles * self.model.n_layers as f64 + lm_head) / self.freq_hz
    }

    /// Per-token cost of AUTOREGRESSIVE decode run on this *spatial*
    /// prefill pipeline, seconds — the fallback cost of decoding on a
    /// prefill-specialized shard. The lag-1 recurrence (token `k`'s
    /// input is token `k-1`'s sample) drains the dataflow pipeline on
    /// every token, so the cost collapses toward the serialized sum of
    /// stage services instead of the bottleneck stage — exactly why the
    /// paper gives decode its own temporally-reused engine, and why a
    /// disaggregated serving layer migrates decode work off prefill
    /// shards instead of running it in place.
    pub fn recurrent_decode_latency_s(&self, ctx: u64) -> f64 {
        let graph = build_graph(&self.cfg, &self.model, ctx.max(1));
        // a few steps amortize the pipeline-fill transient out of the
        // per-token figure
        let steps = 4u64;
        let r = simulate_recurrent(&graph, steps);
        let lm_head =
            self.model.d_model as f64 * self.model.vocab as f64 / self.cfg.wp_ffn as f64;
        (r.makespan_cycles / steps as f64 * self.model.n_layers as f64 + lm_head)
            / self.freq_hz
    }

    /// Simulate one decoder layer over `l_p` tokens.
    pub fn simulate(&self, l_p: u64) -> SimResult {
        let graph = build_graph(&self.cfg, &self.model, l_p);
        simulate(&graph, l_p, &[])
    }

    pub fn utilization(&self) -> Resources {
        self.device.utilization(&self.resources)
    }

    /// Table IV-style module inventory for this design.
    pub fn graph(&self, l_p: u64) -> DataflowGraph {
        build_graph(&self.cfg, &self.model, l_p)
    }
}

/// Compose the Fig. 5(a) graph for one decoder layer at context `ctx`.
fn build_graph(cfg: &PrefillConfig, m: &ModelDims, ctx: u64) -> DataflowGraph {
    let mut g = DataflowGraph::new();
    let d = m.d_model;
    let tp = cfg.tp;

    // input dynamic INT4 quantizer (per-token asym) — feeds every linear:
    // reused for attention input, FFN input and FHT output (3 sites)
    let quant_in = g.invoke_reused(
        Arc::new(Quantizer::new("pref_quant_dyn_int4", true, false, true, tp, d, 4)),
        3.0, 1);

    // Q/K shared linear (Fig. 4 / Fig. 5(a)): roles K (d→d_kv) and Q (d→d)
    let lin_kq = g.invoke_reused(
        Arc::new(PrefillLinear::new("pref_linear_kq", tp, cfg.wp_kqvo, d,
                                    (d + m.d_kv) / 2, Precision::Int4)),
        2.0, 1);
    // V/O shared linear: roles V (d→d_kv) and O (d→d)
    let lin_vo = g.invoke_reused(
        Arc::new(PrefillLinear::new("pref_linear_vo", tp, cfg.wp_kqvo, d,
                                    (d + m.d_kv) / 2, Precision::Int4)),
        2.0, 1);
    // shared RoPE for Q and K
    let rope = g.invoke_reused(
        Arc::new(NonLinear::new("pref_rope_kq", NonLinearKind::RoPE, tp, d)), 2.0, 1);
    // static INT8 quantizers for q/k/v (KV8)
    let quant_kv = g.invoke_reused(
        Arc::new(Quantizer::new("pref_quant_sta_int8", false, true, false, tp, d, 8)),
        3.0, 1);
    let kv_store = g.invoke(Arc::new(KvCache::new("pref_kv_cache", m.d_kv, Precision::Int8)));

    // MHA: two INT8 engines streaming KV from HBM
    let mha_qk = g.invoke(Arc::new(MhaEngine::prefill(
        "pref_mha_qk", tp, cfg.wp_mha, d, m.d_kv, ctx, m.n_heads)));
    let softmax = g.invoke(Arc::new(NonLinear::new("pref_softmax", NonLinearKind::Softmax,
                                                   tp, ctx.max(1))));
    let mha_pv = g.invoke(Arc::new(MhaEngine::prefill(
        "pref_mha_pv", tp, cfg.wp_mha, d, m.d_kv, ctx, m.n_heads)));

    // dequantizer shared across all integer linears (7 sites/layer)
    let dequant = g.invoke_reused(
        Arc::new(Dequantizer::new("pref_dequant", tp, d.max(m.d_ffn), true)), 4.0, 1);

    // norms and residuals (2 sites each per layer)
    let norm = g.invoke_reused(
        Arc::new(NonLinear::new("pref_rmsnorm", NonLinearKind::RmsNorm, tp, d)), 2.0, 1);
    let resid = g.invoke_reused(
        Arc::new(NonLinear::new("pref_residual", NonLinearKind::Residual, tp, d)), 2.0, 1);

    // FFN: three dedicated INT4 linears + swish/gate + FHT
    let lin_gate = g.invoke(Arc::new(PrefillLinear::new(
        "pref_linear_gate", tp, cfg.wp_ffn, d, m.d_ffn, Precision::Int4)));
    let lin_up = g.invoke(Arc::new(PrefillLinear::new(
        "pref_linear_up", tp, cfg.wp_ffn, d, m.d_ffn, Precision::Int4)));
    let swish = g.invoke(Arc::new(NonLinear::new("pref_swish", NonLinearKind::Swish,
                                                 tp, m.d_ffn)));
    let gate = g.invoke(Arc::new(NonLinear::new("pref_gate", NonLinearKind::Gate,
                                                tp, m.d_ffn)));
    let fht = g.invoke(Arc::new(FhtModule::new("pref_fht",
                                               tp, m.d_ffn.next_power_of_two())));
    let lin_down = g.invoke(Arc::new(PrefillLinear::new(
        "pref_linear_down", tp, cfg.wp_ffn, m.d_ffn, d, Precision::Int4)));

    // streaming topology (token-granularity chain; K/V precede attention)
    let s = || StreamEdge::activation(tp);
    g.connect(quant_in, lin_kq, s());
    g.connect(quant_in, lin_vo, s());
    g.connect(lin_kq, rope, s());
    g.connect(rope, quant_kv, s());
    g.connect(quant_kv, kv_store, s());
    g.connect(kv_store, mha_qk, s());
    g.connect(mha_qk, softmax, s());
    g.connect(softmax, mha_pv, s());
    g.connect(mha_pv, dequant, s());
    g.connect(dequant, resid, s());
    g.connect(resid, norm, s());
    g.connect(norm, lin_gate, s());
    g.connect(norm, lin_up, s());
    g.connect(lin_gate, swish, s());
    g.connect(lin_up, gate, s());
    g.connect(swish, gate, s());
    g.connect(gate, fht, s());
    g.connect(fht, lin_down, s());
    g.connect(lin_vo, resid, s());
    g
}

#[cfg(test)]
mod tests {
    use super::*;

    fn u280_arch() -> PrefillArch {
        PrefillArch::new(PrefillConfig::u280_paper(), ModelDims::llama32_1b(),
                         DeviceConfig::u280())
    }

    #[test]
    fn table_vi_u280_prefill_latency() {
        // Paper: 1.65 s / 1k tokens at 304 MHz. Accept ±15%.
        let a = u280_arch();
        let t = a.analytic_latency_s(1024);
        assert!(t > 1.65 * 0.85 && t < 1.65 * 1.15, "latency = {t}");
    }

    #[test]
    fn table_vi_u280_prefill_frequency() {
        let a = u280_arch();
        let mhz = a.freq_hz / 1e6;
        assert!(mhz > 285.0 && mhz < 320.0, "freq = {mhz} MHz");
    }

    #[test]
    fn eq5_bandwidth_under_device_cap() {
        let a = u280_arch();
        assert!(a.peak_bandwidth() < a.device.hbm_bw,
                "prefill BW {} exceeds U280 {}", a.peak_bandwidth(), a.device.hbm_bw);
    }

    #[test]
    fn resources_fit_u280() {
        let a = u280_arch();
        let u = a.utilization();
        assert!(u.max_class() < 0.9, "binding util = {}", u.max_class());
        assert!(u.max_class() > 0.3, "implausibly small design: {}", u.max_class());
    }

    #[test]
    fn sim_close_to_analytic() {
        let a = u280_arch();
        let sim = a.simulated_latency_s(512);
        let ana = a.analytic_latency_s(512);
        let ratio = sim / ana;
        assert!(ratio > 0.7 && ratio < 1.6, "sim/analytic = {ratio}");
    }

    #[test]
    fn chunk_latency_is_proportional_with_fill_overhead() {
        // a chunk costs its share of the prompt plus the pipeline-fill
        // transient; four 32-token chunks therefore cost at least the
        // 128-token prompt but within ~2x of it
        let a = u280_arch();
        let full = a.simulated_chunk_latency_s(128, 128, true);
        let chunks = 3.0 * a.simulated_chunk_latency_s(32, 128, false)
            + a.simulated_chunk_latency_s(32, 128, true);
        assert!(chunks >= full * 0.99, "chunks {chunks} < full {full}");
        assert!(chunks < full * 2.0, "chunk overhead blew up: {chunks} vs {full}");
        // lm_head only charged when asked
        assert!(a.simulated_chunk_latency_s(32, 128, true)
                > a.simulated_chunk_latency_s(32, 128, false));
    }

    #[test]
    fn latency_scales_superlinearly_with_context() {
        // attention term grows with l_p → >2× latency at 2× tokens once
        // MHA dominates
        let a = u280_arch();
        let t1 = a.analytic_latency_s(4096);
        let t2 = a.analytic_latency_s(8192);
        assert!(t2 > 2.0 * t1);
    }

    #[test]
    fn spatial_decode_fallback_much_slower_than_temporal() {
        // decode on the prefill pipeline pays the full pipeline drain
        // per token — the cross-role penalty priced by the disaggregated
        // serving layer must actually exist
        let p = u280_arch();
        let d = crate::arch::DecodeArch::new(
            crate::arch::DecodeConfig::u280_paper(),
            ModelDims::llama32_1b(),
            DeviceConfig::u280(),
        );
        let spatial = p.recurrent_decode_latency_s(512);
        let temporal = d.per_token_latency_s(512);
        assert!(spatial > 2.0 * temporal,
                "spatial decode {spatial} not clearly slower than temporal {temporal}");
    }

    #[test]
    fn v80_faster_than_u280() {
        let u = u280_arch();
        let v = PrefillArch::new(PrefillConfig::v80_paper(), ModelDims::llama32_1b(),
                                 DeviceConfig::v80());
        assert!(v.analytic_latency_s(1024) < u.analytic_latency_s(1024) / 2.0);
    }
}
