//! Stage-customized architectures (paper Sec. IV/V) and baselines.
//!
//! * [`PrefillArch`] — hybrid streaming prefill (Fig. 5(a), Eq. 4/5)
//! * [`DecodeArch`] — temporally-reused wide decode (Fig. 5(b), Eq. 6/7)
//! * [`HmtPlugin`] — long-context memory plug-in (Fig. 5(c))
//! * [`TemporalBaseline`] — FlightLLM-like monolithic engine (Fig. 1(b,c))
//! * [`SpatialBaseline`] — Allo-like unified dataflow (Fig. 1(d,e))

mod decode;
mod hmt;
mod prefill;
mod spatial_baseline;
mod temporal_baseline;

pub use decode::{DecodeArch, DecodeConfig};
pub use hmt::{hmt_prefill_latency_s, HmtConfig, HmtPlugin};
pub use prefill::{PrefillArch, PrefillConfig};
pub use spatial_baseline::{AlloBaseline, SpatialBaseline, UnifiedAlloBaseline};
pub use temporal_baseline::TemporalBaseline;

use crate::config::{DeviceConfig, ModelDims};

/// How many same-stage engines a *role-specialized* shard hosts on the
/// fabric budget of one [`AcceleratorSystem`].
///
/// A `Unified` serving shard carries one prefill pipeline AND one decode
/// engine (the paper's two stage-customized designs time-sharing a
/// device via rapid reconfiguration). A shard typed `Prefill` or
/// `Decode` drops the other stage entirely, and the freed fabric hosts a
/// second instance of its own stage: both paper designs bind under ~55%
/// of the U280 per resource class (`resources_fit_u280` pins < 0.92 for
/// the binding class alone), so two same-stage replicas close at the
/// same budget two different-stage designs do. The modeled effect —
/// [`crate::coordinator::ModeledBackend`] applies it — is chunk latency
/// ÷ 2 on a prefill specialist and decode batch width × 2 on a decode
/// specialist, while the *off-role* path is priced by the honest
/// fallback costs ([`PrefillArch::recurrent_decode_latency_s`],
/// [`DecodeArch::chunk_prefill_latency_s`]) rather than assumed away.
pub const STAGE_REPLICAS: usize = 2;

/// A full stage-customized accelerator system: prefill + decode + HMT
/// sharing one device via rapid reconfiguration (~0.3 s on U280).
/// `Clone` replicates the system per device — multi-engine sharding
/// instantiates one modeled system per shard.
#[derive(Debug, Clone)]
pub struct AcceleratorSystem {
    pub prefill: PrefillArch,
    pub decode: DecodeArch,
    pub hmt: HmtPlugin,
    /// Bitstream reconfiguration time between stages, seconds.
    pub reconfig_s: f64,
}

impl AcceleratorSystem {
    pub fn u280() -> Self {
        let model = ModelDims::llama32_1b();
        AcceleratorSystem {
            prefill: PrefillArch::new(PrefillConfig::u280_paper(), model.clone(),
                                      DeviceConfig::u280()),
            decode: DecodeArch::new(DecodeConfig::u280_paper(), model.clone(),
                                    DeviceConfig::u280()),
            hmt: HmtPlugin::new(HmtConfig::u280_paper(), model, DeviceConfig::u280()),
            reconfig_s: 0.3,
        }
    }

    pub fn v80() -> Self {
        let model = ModelDims::llama32_1b();
        AcceleratorSystem {
            prefill: PrefillArch::new(PrefillConfig::v80_paper(), model.clone(),
                                      DeviceConfig::v80()),
            decode: DecodeArch::new(DecodeConfig::v80_paper(), model.clone(),
                                    DeviceConfig::v80()),
            hmt: HmtPlugin::new(HmtConfig::v80_paper(), model, DeviceConfig::v80()),
            reconfig_s: 0.3,
        }
    }

    /// End-to-end latency for a [prefill, decode] workload (Fig. 7 x-axis),
    /// including the stage-switch reconfiguration.
    pub fn e2e_latency_s(&self, l_p: u64, l_d: u64) -> f64 {
        self.prefill.analytic_latency_s(l_p)
            + self.reconfig_s
            + self.decode.analytic_latency_s(l_p, l_d)
    }

    /// Decode tokens/second for the workload.
    pub fn decode_throughput(&self, l_p: u64, l_d: u64) -> f64 {
        self.decode.decode_throughput(l_p, l_d)
    }

    /// Tokens per joule over the full request (average board power).
    pub fn tokens_per_joule(&self, l_p: u64, l_d: u64) -> f64 {
        let t = self.e2e_latency_s(l_p, l_d);
        l_d as f64 / (t * self.decode.device.avg_power_w)
    }

    /// HMT-enhanced prefill latency over a long context.
    pub fn hmt_prefill_s(&self, total_ctx: u64) -> f64 {
        hmt_prefill_latency_s(&self.hmt, |seg| self.prefill.analytic_latency_s(seg),
                              self.prefill.freq_hz, total_ctx)
    }

    /// HMT-enhanced decode: the attention context stays capped at one
    /// segment + the memory queue (generated tokens fold into new
    /// segments), so per-token cost is flat in both prompt and output
    /// length — the paper's quadratic→linear conversion.
    pub fn hmt_decode_latency_s(&self, l_d: u64) -> f64 {
        let eff_ctx = self.hmt.cfg.segment_len + self.hmt.cfg.n_memories;
        l_d as f64 * self.decode.per_token_latency_s(eff_ctx)
            + (l_d as f64 / self.hmt.cfg.segment_len as f64).ceil()
                * self.hmt.seconds_per_segment(self.decode.freq_hz)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn u280_system_composes() {
        let s = AcceleratorSystem::u280();
        let t = s.e2e_latency_s(1024, 1024);
        assert!(t > 0.0 && t.is_finite());
        assert!(s.decode_throughput(1024, 1024) > 50.0);
    }

    #[test]
    fn hmt_prefill_beats_full_attention_at_64k() {
        // paper: prefill latency reduced up to 23.23× at long context
        let s = AcceleratorSystem::u280();
        let full = s.prefill.analytic_latency_s(65_536);
        let hmt = s.hmt_prefill_s(65_536);
        let gain = full / hmt;
        assert!(gain > 10.0, "HMT prefill gain = {gain}");
    }

    #[test]
    fn hmt_decode_flat_in_context() {
        let s = AcceleratorSystem::u280();
        let a = s.hmt_decode_latency_s(256);
        // HMT decode cost does not depend on the original prompt length
        assert!(a.is_finite() && a > 0.0);
    }
}
