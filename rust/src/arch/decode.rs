//! Stage-customized **decode** architecture (paper Fig. 5(b), Eq. 6/7).
//!
//! Autoregressive dependencies kill inter-token parallelism, so the
//! design temporally reuses one wide INT4 linear engine for every
//! projection / FFN / lm_head computation across all layers, keeps two
//! INT8 MHA engines streaming the KV cache, and exploits intra-token
//! block parallelism (BP) plus inter-head overlap. The wide engine is
//! partitioned into identical submodules for floorplanning (Sec. IV-B).

use std::sync::Arc;

use crate::config::{DeviceConfig, ModelDims, Precision};
use crate::hls::calibration::MEASURED_OVERHEAD_DECODE;
use crate::hls::{
    achieved_frequency, partition_for_frequency, simulate_recurrent, DataflowGraph,
    DecodeLinear, Dequantizer, FhtModule, KvCache, MhaEngine, NonLinear, NonLinearKind,
    Quantizer, Resources, Sampling, SimResult, StreamEdge,
};

/// The tunable knobs of the decode architecture (Table VI rows 3/6).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DecodeConfig {
    pub bp: u64,
    pub wp_int4: u64,
    pub wp_mha: u64,
}

impl DecodeConfig {
    /// The paper's U280 configuration.
    pub fn u280_paper() -> Self {
        DecodeConfig { bp: 16, wp_int4: 1024, wp_mha: 256 }
    }

    /// The paper's V80 configuration.
    pub fn v80_paper() -> Self {
        DecodeConfig { bp: 64, wp_int4: 4096, wp_mha: 1024 }
    }
}

/// A composed decode accelerator instance.
#[derive(Debug, Clone)]
pub struct DecodeArch {
    pub cfg: DecodeConfig,
    pub model: ModelDims,
    pub device: DeviceConfig,
    pub resources: Resources,
    pub freq_hz: f64,
    pub partitions: u64,
}

impl DecodeArch {
    pub fn new(cfg: DecodeConfig, model: ModelDims, device: DeviceConfig) -> Self {
        let partitions = partition_for_frequency(cfg.wp_int4);
        let graph = build_graph(&cfg, &model, 1024, partitions);
        let resources = (graph.resources() + crate::hls::calibration::platform_overhead())
            .with_derived_clb();
        let util = device.utilization(&resources).max_class();
        let freq_hz = achieved_frequency(&device, util, cfg.wp_int4 / partitions);
        DecodeArch { cfg, model, device, resources, freq_hz, partitions }
    }

    /// Serial integer-linear MACs per token (numerator of Eq. 6 term 1:
    /// q/k/v projections + FFN + lm_head; the O projection overlaps with
    /// MHA and lives in the max term).
    fn linear_macs(&self) -> f64 {
        let m = &self.model;
        (m.n_layers * (2 * m.d_model * m.d_kv + m.d_model * m.d_model
            + 3 * m.d_model * m.d_ffn) + m.d_model * m.vocab) as f64
    }

    /// Eq. 6 per-token decode latency at a given attention context.
    pub fn per_token_latency_s(&self, avg_ctx: u64) -> f64 {
        let m = &self.model;
        let c = &self.cfg;
        let d = m.d_model as f64;
        let n = m.n_layers as f64;
        let serial = self.linear_macs() / c.wp_int4 as f64;
        let overlap = (n * d * d / c.wp_int4 as f64)
            .max(n * d * avg_ctx as f64 / c.wp_mha as f64);
        (serial + overlap) / self.freq_hz * MEASURED_OVERHEAD_DECODE
    }

    /// Eq. 6 closed-form decode latency, seconds, for `l_d` generated
    /// tokens after a prompt of `l_p` (avg context l_p + l_d/2).
    pub fn analytic_latency_s(&self, l_p: u64, l_d: u64) -> f64 {
        l_d as f64 * self.per_token_latency_s(l_p + l_d / 2)
    }

    /// Tokens/second at the given context (1 / per-token latency).
    pub fn decode_throughput(&self, l_p: u64, l_d: u64) -> f64 {
        l_d as f64 / self.analytic_latency_s(l_p, l_d)
    }

    /// Eq. 7 peak bandwidth demand, bytes/second.
    pub fn peak_bandwidth(&self) -> f64 {
        self.freq_hz
            * (Precision::Int4.bytes() * self.cfg.wp_int4 as f64
                + 2.0 * Precision::Int8.bytes() * self.cfg.wp_mha as f64)
    }

    /// Effective decode bandwidth utilization (the Sec. VI-B1 comparison:
    /// bytes actually moved per second / device peak).
    pub fn bandwidth_utilization(&self, l_p: u64, l_d: u64) -> f64 {
        let m = &self.model;
        let weights = m.decode_weight_bytes(Precision::Int4.bytes(), Precision::Int4.bytes());
        let kv = m.kv_bytes_per_token(l_p + l_d / 2, Precision::Int8.bytes());
        let per_token_s = self.analytic_latency_s(l_p, l_d) / l_d as f64;
        ((weights + kv) / per_token_s) / self.device.hbm_bw
    }

    /// Stall-aware latency from the dataflow simulator, seconds.
    pub fn simulated_latency_s(&self, l_p: u64, l_d: u64) -> f64 {
        self.simulate(l_p, l_d).makespan_cycles / self.freq_hz
    }

    /// Simulate `l_d` autoregressive steps (recurrence lag 1: the
    /// sampling output feeds the next token's first module).
    pub fn simulate(&self, l_p: u64, l_d: u64) -> SimResult {
        let avg_ctx = l_p + l_d / 2;
        let graph = build_graph(&self.cfg, &self.model, avg_ctx, self.partitions);
        simulate_recurrent(&graph, l_d)
    }

    /// Price streaming `tokens` **prompt** tokens through this *temporal*
    /// engine with attention sized for end context `end_ctx`, seconds —
    /// the fallback cost of running prefill on a decode-specialized
    /// shard. The single wide linear engine serializes every projection,
    /// so prompt tokens cost the same as generated ones; the lm_head
    /// MACs folded into [`Self::per_token_latency_s`] slightly over-price
    /// intermediate prompt tokens (which never sample), erring against
    /// the fallback path — honest for a cross-role placement penalty.
    pub fn chunk_prefill_latency_s(&self, tokens: u64, end_ctx: u64) -> f64 {
        tokens as f64 * self.per_token_latency_s(end_ctx.max(1))
    }

    pub fn utilization(&self) -> Resources {
        self.device.utilization(&self.resources)
    }

    pub fn graph(&self, avg_ctx: u64) -> DataflowGraph {
        build_graph(&self.cfg, &self.model, avg_ctx, self.partitions)
    }
}

/// Compose the Fig. 5(b) graph: one full token step across all layers.
fn build_graph(cfg: &DecodeConfig, m: &ModelDims, avg_ctx: u64, partitions: u64) -> DataflowGraph {
    let mut g = DataflowGraph::new();
    let d = m.d_model;
    let n = m.n_layers as f64;
    let bp = cfg.bp;

    // dynamic INT4 quantizer: attention input + FFN input + FHT output per layer
    let quant_in = g.invoke_reused(
        Arc::new(Quantizer::new("dec_quant_dyn_int4", true, false, true, bp, d, 4)),
        3.0 * n, 1);

    // THE shared INT4 linear engine: all projections + FFN + lm_head.
    // Aggregate reuse = total MACs / (d·d) with a d×d-dim template.
    let total_macs = (m.n_layers * (2 * d * m.d_kv + 2 * d * d + 3 * d * m.d_ffn)
        + d * m.vocab) as f64;
    let linear = g.invoke_reused(
        Arc::new(DecodeLinear::new("dec_linear_int4", bp, cfg.wp_int4, d, d, Precision::Int4)
            .with_partitions(partitions)),
        total_macs / (d * d) as f64, 1);

    let rope = g.invoke_reused(
        Arc::new(NonLinear::new("dec_rope", NonLinearKind::RoPE, bp, d)), 2.0 * n, 1);
    let quant_kv = g.invoke_reused(
        Arc::new(Quantizer::new("dec_quant_sta_int8", false, true, false, bp, d, 8)),
        3.0 * n, 1);
    let kv_store = g.invoke_reused(
        Arc::new(KvCache::new("dec_kv_cache", m.d_kv, Precision::Int8)), n, 1);

    // two INT8 MHA engines per the paper (QKᵀ and PV), reused across layers
    let mha_qk = g.invoke_reused(
        Arc::new(MhaEngine::decode("dec_mha_qk", cfg.wp_mha, d, m.d_kv, avg_ctx, m.n_heads)),
        n, 1);
    let softmax = g.invoke_reused(
        Arc::new(NonLinear::new("dec_softmax", NonLinearKind::Softmax, bp, avg_ctx.max(1))),
        n, 1);
    let mha_pv = g.invoke_reused(
        Arc::new(MhaEngine::decode("dec_mha_pv", cfg.wp_mha, d, m.d_kv, avg_ctx, m.n_heads)),
        n, 1);

    let dequant = g.invoke_reused(
        Arc::new(Dequantizer::new("dec_dequant", bp, d.max(m.d_ffn), true)), 4.0 * n, 1);
    let norm = g.invoke_reused(
        Arc::new(NonLinear::new("dec_rmsnorm", NonLinearKind::RmsNorm, bp, d)), 2.0 * n, 1);
    let resid = g.invoke_reused(
        Arc::new(NonLinear::new("dec_residual", NonLinearKind::Residual, bp, d)), 2.0 * n, 1);
    let swish = g.invoke_reused(
        Arc::new(NonLinear::new("dec_swish", NonLinearKind::Swish, bp, m.d_ffn)), n, 1);
    let gate = g.invoke_reused(
        Arc::new(NonLinear::new("dec_gate", NonLinearKind::Gate, bp, m.d_ffn)), n, 1);
    let fht = g.invoke_reused(
        Arc::new(FhtModule::new("dec_fht", bp, m.d_ffn.next_power_of_two())), n, 1);
    let sampling = g.invoke(Arc::new(Sampling::new("dec_sampling", m.vocab, bp)));

    let s = || StreamEdge::activation(bp);
    g.connect(quant_in, linear, s());
    g.connect(linear, rope, s());
    g.connect(rope, quant_kv, s());
    g.connect(quant_kv, kv_store, s());
    g.connect(kv_store, mha_qk, s());
    g.connect(mha_qk, softmax, s());
    g.connect(softmax, mha_pv, s());
    g.connect(mha_pv, dequant, s());
    g.connect(dequant, resid, s());
    g.connect(resid, norm, s());
    g.connect(norm, swish, s());
    g.connect(swish, gate, s());
    g.connect(gate, fht, s());
    g.connect(fht, sampling, s());
    g
}

#[cfg(test)]
mod tests {
    use super::*;

    fn u280_arch() -> DecodeArch {
        DecodeArch::new(DecodeConfig::u280_paper(), ModelDims::llama32_1b(),
                        DeviceConfig::u280())
    }

    #[test]
    fn table_vi_u280_decode_latency() {
        // Paper: 6.94 s / 1k tokens (l_p = 1024 workload). Accept ±25%
        // (the paper's measured number includes board effects the model
        // can only approximate).
        let a = u280_arch();
        let t = a.analytic_latency_s(1024, 1024);
        assert!(t > 6.94 * 0.7 && t < 6.94 * 1.3, "latency = {t}");
    }

    #[test]
    fn eq7_bandwidth_near_but_under_cap() {
        // Decode is tuned to saturate bandwidth: close to, but below, 460 GB/s.
        let a = u280_arch();
        let bw = a.peak_bandwidth();
        assert!(bw < a.device.hbm_bw, "BW {bw} exceeds cap");
        assert!(bw > 0.5 * a.device.hbm_bw, "decode should stress HBM, bw = {bw}");
    }

    #[test]
    fn resources_fit_u280() {
        let a = u280_arch();
        let u = a.utilization();
        assert!(u.max_class() < 0.92, "binding util = {}", u.max_class());
        assert!(u.max_class() > 0.35);
    }

    #[test]
    fn decode_engine_partitioned() {
        let a = u280_arch();
        assert!(a.partitions >= 2, "WP=1024 engine must be partitioned");
    }

    #[test]
    fn throughput_falls_with_context() {
        let a = u280_arch();
        assert!(a.decode_throughput(512, 512) > a.decode_throughput(4096, 512));
    }

    #[test]
    fn sim_close_to_analytic() {
        let a = u280_arch();
        let sim = a.simulated_latency_s(1024, 256);
        let ana = a.analytic_latency_s(1024, 256);
        let ratio = sim / ana;
        assert!(ratio > 0.6 && ratio < 1.7, "sim/analytic = {ratio}");
    }

    #[test]
    fn v80_decode_much_faster() {
        let u = u280_arch();
        let v = DecodeArch::new(DecodeConfig::v80_paper(), ModelDims::llama32_1b(),
                                DeviceConfig::v80());
        // paper: 1.68 vs 6.94 s/1k → ~4×
        let ru = u.analytic_latency_s(1024, 1024);
        let rv = v.analytic_latency_s(1024, 1024);
        assert!(ru / rv > 2.5, "U280/V80 = {}", ru / rv);
    }

    #[test]
    fn temporal_prefill_fallback_much_slower_than_spatial() {
        // prefill on the decode engine serializes every prompt token
        // through the one wide linear — the cross-role penalty the
        // disaggregated serving layer prices must actually exist
        let d = u280_arch();
        let p = crate::arch::PrefillArch::new(
            crate::arch::PrefillConfig::u280_paper(),
            ModelDims::llama32_1b(),
            DeviceConfig::u280(),
        );
        let spatial = p.simulated_chunk_latency_s(256, 256, true);
        let temporal = d.chunk_prefill_latency_s(256, 256);
        assert!(temporal > 2.0 * spatial,
                "temporal prefill {temporal} not clearly slower than spatial {spatial}");
    }

    #[test]
    fn bandwidth_utilization_sane() {
        let a = u280_arch();
        let u = a.bandwidth_utilization(1024, 1024);
        assert!(u > 0.15 && u < 1.0, "bw util = {u}");
    }
}
