//! Table V harness core: execute the per-scheme PPL artifacts on the
//! held-out corpus and compute perplexity in Rust (cross-checked against
//! the build-time Python numbers within 2%).

use crate::anyhow::{anyhow, Result};

use crate::runtime::{lit_i32, nll_from_logits, to_f32, Runtime};

/// Load the held-out eval batches baked by aot.py.
pub fn load_eval_tokens(rt: &Runtime) -> Result<Vec<Vec<i32>>> {
    let e = &rt.manifest.eval;
    let path = rt.dir().join("eval_tokens.bin");
    let bytes = std::fs::read(&path).map_err(|err| anyhow!("reading {path:?}: {err}"))?;
    let want = e.n_batches * e.batch * e.seq * 4;
    if bytes.len() != want {
        return Err(anyhow!("eval_tokens.bin: {} bytes, want {want}", bytes.len()));
    }
    let all: Vec<i32> = bytes
        .chunks_exact(4)
        .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect();
    Ok(all
        .chunks_exact(e.batch * e.seq)
        .map(|c| c.to_vec())
        .collect())
}

/// Perplexity of one scheme over the eval batches.
pub fn scheme_ppl(rt: &Runtime, scheme: &str) -> Result<f64> {
    let e = &rt.manifest.eval;
    let v = rt.manifest.model.vocab as usize;
    let name = format!("ppl_{scheme}");
    let mut total = 0.0f64;
    let mut count = 0usize;
    for batch in load_eval_tokens(rt)? {
        let tokens = lit_i32(&batch, &[e.batch as i64, e.seq as i64])?;
        let out = rt.execute(&name, &[tokens])?;
        let logits = to_f32(&out[0])?;
        let (t, c) = nll_from_logits(&logits, &batch, e.batch, e.seq, v);
        total += t;
        count += c;
    }
    Ok((total / count as f64).exp())
}

/// Run the full ablation; returns (scheme, measured ppl) in Table V order
/// and verifies each against the build-time Python value (2% tolerance).
pub fn run(rt: &Runtime) -> Result<Vec<(String, f64)>> {
    let mut out = Vec::new();
    for scheme in crate::runtime::Manifest::scheme_order() {
        let ppl = scheme_ppl(rt, scheme)?;
        if let Some(stats) = rt.manifest.schemes.get(scheme) {
            let rel = (ppl - stats.ppl).abs() / stats.ppl;
            if rel > 0.02 {
                return Err(anyhow!(
                    "{scheme}: rust ppl {ppl:.3} deviates {rel:.1}% from build-time {:.3} — \
                     artifact/runtime mismatch",
                    stats.ppl
                ));
            }
        }
        out.push((scheme.to_string(), ppl));
    }
    Ok(out)
}
