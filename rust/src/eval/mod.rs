//! Evaluation harness: regenerates every table and figure in the paper
//! (see DESIGN.md §6 for the experiment index).

pub mod ablation;
pub mod figures;
pub mod tables;

pub use figures::{fig1, fig2, fig6, fig7, fig7_csv, fig7_data, fig7_headline, fig8,
                  fig8_data, FIG7_GRID, FIG8_CONTEXTS};
pub use tables::{table1, table2, table3, table4, table5, table6, table6_deltas};
