//! Figure generators (paper Figs. 1, 2, 6, 7, 8) — printed as tables /
//! CSV series carrying the same data the paper plots.

use crate::arch::{AcceleratorSystem, AlloBaseline, SpatialBaseline, TemporalBaseline,
                  UnifiedAlloBaseline};
use crate::config::ModelDims;
use crate::gpu_model::{GpuBaseline, GpuMode};
use crate::report::{csv, fmt_pct, fmt_ratio, fmt_secs, table};

/// The Fig. 7 workload grid: [prefill, decode] length pairs.
pub const FIG7_GRID: [(u64, u64); 8] = [
    (512, 256), (512, 512), (512, 1024), (512, 2048),
    (1024, 256), (1024, 512), (1024, 1024), (1024, 2048),
];

/// Fig. 1: temporal / spatial / hybrid behaviour on the same workload —
/// pipeline utilization and relative latency from the dataflow simulator.
pub fn fig1() -> String {
    let model = ModelDims::llama32_1b();
    let sys = AcceleratorSystem::u280();
    let temporal = TemporalBaseline::u280();
    let spatial = SpatialBaseline::u280_allo();
    let unified = UnifiedAlloBaseline::u280();
    let (lp, ld) = (1024, 256);

    let rows = vec![
        vec!["Temporal (FlightLLM-like)".into(),
             fmt_secs(temporal.prefill_latency_s(lp)),
             fmt_secs(temporal.decode_latency_s(lp, ld)),
             "high engine util, off-chip spills".into()],
        vec!["Spatial unified (Allo-like)".into(),
             fmt_secs(spatial.prefill_latency_s(lp)),
             fmt_secs(spatial.decode_latency_s(lp, ld)),
             format!("decode pipeline util {}",
                     fmt_pct(spatial.decode_utilization(lp, ld)))],
        vec!["Hybrid unified config (ablation)".into(),
             fmt_secs(unified.prefill_latency_s(lp)),
             fmt_secs(unified.decode_latency_s(lp, ld)),
             "one config for both stages".into()],
        vec!["Hybrid stage-customized (FlexLLM)".into(),
             fmt_secs(sys.prefill.analytic_latency_s(lp)),
             fmt_secs(sys.decode.analytic_latency_s(lp, ld)),
             format!("prefill sim util {}",
                     fmt_pct(sys.prefill.simulate(256).mean_utilization))],
    ];
    let _ = model;
    table(&format!("Fig. 1 — architecture styles on [{lp}, {ld}] (U280)"),
          &["Architecture", "Prefill", "Decode", "Notes"], &rows)
}

/// Fig. 2: A100 compute / bandwidth utilization in prefill vs decode.
pub fn fig2() -> String {
    let g = GpuBaseline::a100(ModelDims::llama32_1b(), GpuMode::Bf16);
    let f = g.fig2_utilization(1024, 1024);
    let rows = vec![
        vec!["Prefill (1k tokens)".into(), fmt_pct(f.prefill_compute), fmt_pct(f.prefill_bw)],
        vec!["Decode (1k tokens)".into(), fmt_pct(f.decode_compute), fmt_pct(f.decode_bw)],
    ];
    table("Fig. 2 — A100 BF16 Llama-3.2 1B stage utilization (modeled)",
          &["Stage", "Compute util", "HBM BW util"], &rows)
}

/// Fig. 6: implementation layout — rendered as per-kind resource shares.
pub fn fig6() -> String {
    let sys = AcceleratorSystem::u280();
    let mut rows = Vec::new();
    for (stage, graph) in [("Prefill", sys.prefill.graph(1024)),
                           ("Decode", sys.decode.graph(1024))] {
        for (kind, count, res) in graph.kind_breakdown() {
            rows.push(vec![
                stage.to_string(),
                kind.name().to_string(),
                count.to_string(),
                format!("{:.0}", res.lut),
                format!("{:.0}", res.dsp),
                format!("{:.0}", res.bram),
            ]);
        }
    }
    table("Fig. 6 — U280 layout (module-kind resource breakdown)",
          &["Stage", "Module kind", "Instances", "LUT", "DSP", "BRAM"], &rows)
}

/// One Fig. 7 measurement row across all five systems.
#[derive(Debug)]
pub struct Fig7Row {
    pub lp: u64,
    pub ld: u64,
    pub e2e: [f64; 5],
    pub tput: [f64; 5],
    pub tpj: [f64; 5],
}

pub const FIG7_SYSTEMS: [&str; 5] =
    ["A100 BF16", "A100 GPTQ-Marlin", "Allo (U280)", "FlexLLM U280", "FlexLLM V80"];

/// Compute the Fig. 7 grid.
pub fn fig7_data() -> Vec<Fig7Row> {
    let model = ModelDims::llama32_1b();
    let bf16 = GpuBaseline::a100(model.clone(), GpuMode::Bf16);
    let gptq = GpuBaseline::a100(model.clone(), GpuMode::GptqMarlinInt4);
    let allo = AlloBaseline::u280();
    let u280 = AcceleratorSystem::u280();
    let v80 = AcceleratorSystem::v80();
    // Allo board power comparable to the FlexLLM U280 design
    let allo_power = allo.decode.device.avg_power_w * 1.02;

    FIG7_GRID
        .iter()
        .map(|&(lp, ld)| {
            let allo_e2e = allo.e2e_latency_s(lp, ld);
            let allo_tput = ld as f64 / allo.decode_latency_s(lp, ld);
            let allo_tpj = ld as f64 / (allo_e2e * allo_power);
            Fig7Row {
                lp,
                ld,
                e2e: [bf16.e2e_latency_s(lp, ld), gptq.e2e_latency_s(lp, ld), allo_e2e,
                      u280.e2e_latency_s(lp, ld), v80.e2e_latency_s(lp, ld)],
                tput: [bf16.decode_throughput(lp, ld), gptq.decode_throughput(lp, ld),
                       allo_tput, u280.decode_throughput(lp, ld),
                       v80.decode_throughput(lp, ld)],
                tpj: [bf16.tokens_per_joule(lp, ld), gptq.tokens_per_joule(lp, ld), allo_tpj,
                      u280.tokens_per_joule(lp, ld), v80.tokens_per_joule(lp, ld)],
            }
        })
        .collect()
}

/// Fig. 7 rendered: three panels (E2E latency, decode throughput,
/// energy efficiency) + the headline average ratios.
pub fn fig7() -> String {
    let data = fig7_data();
    let mut out = String::new();
    let panel = |title: &str, pick: &dyn Fn(&Fig7Row) -> [f64; 5], fmt: &dyn Fn(f64) -> String| {
        let rows: Vec<Vec<String>> = data
            .iter()
            .map(|r| {
                let vals = pick(r);
                let mut row = vec![format!("[{}, {}]", r.lp, r.ld)];
                row.extend(vals.iter().map(|&v| fmt(v)));
                row
            })
            .collect();
        let headers: Vec<&str> = std::iter::once("[l_p, l_d]").chain(FIG7_SYSTEMS).collect();
        table(title, &headers, &rows)
    };
    out.push_str(&panel("Fig. 7a — end-to-end latency", &|r| r.e2e, &fmt_secs));
    out.push('\n');
    out.push_str(&panel("Fig. 7b — decode throughput (tok/s)", &|r| r.tput,
                        &|v| format!("{v:.1}")));
    out.push('\n');
    out.push_str(&panel("Fig. 7c — energy efficiency (tok/J)", &|r| r.tpj,
                        &|v| format!("{v:.3}")));
    out.push('\n');

    let h = fig7_headline();
    out.push_str(&table(
        "Fig. 7 headline — average ratios vs A100 BF16 (paper: U280 1.29×/1.64×/3.14×, \
         V80 4.71×/6.55×/4.13×; vs Allo 1.46×/1.35×/1.10×)",
        &["System", "E2E speedup", "Decode tput", "Tokens/J"],
        &[
            vec!["FlexLLM U280".into(), fmt_ratio(h.u280_e2e), fmt_ratio(h.u280_tput),
                 fmt_ratio(h.u280_tpj)],
            vec!["FlexLLM V80".into(), fmt_ratio(h.v80_e2e), fmt_ratio(h.v80_tput),
                 fmt_ratio(h.v80_tpj)],
            vec!["U280 vs Allo".into(), fmt_ratio(h.allo_e2e), fmt_ratio(h.allo_tput),
                 fmt_ratio(h.allo_tpj)],
        ],
    ));
    out
}

/// Headline average ratios (the abstract's numbers).
#[derive(Debug)]
pub struct Fig7Headline {
    pub u280_e2e: f64,
    pub u280_tput: f64,
    pub u280_tpj: f64,
    pub v80_e2e: f64,
    pub v80_tput: f64,
    pub v80_tpj: f64,
    pub allo_e2e: f64,
    pub allo_tput: f64,
    pub allo_tpj: f64,
}

pub fn fig7_headline() -> Fig7Headline {
    let data = fig7_data();
    let n = data.len() as f64;
    let mean = |f: &dyn Fn(&Fig7Row) -> f64| data.iter().map(f).sum::<f64>() / n;
    Fig7Headline {
        u280_e2e: mean(&|r| r.e2e[0] / r.e2e[3]),
        u280_tput: mean(&|r| r.tput[3] / r.tput[0]),
        u280_tpj: mean(&|r| r.tpj[3] / r.tpj[0]),
        v80_e2e: mean(&|r| r.e2e[0] / r.e2e[4]),
        v80_tput: mean(&|r| r.tput[4] / r.tput[0]),
        v80_tpj: mean(&|r| r.tpj[4] / r.tpj[0]),
        allo_e2e: mean(&|r| r.e2e[2] / r.e2e[3]),
        allo_tput: mean(&|r| r.tput[3] / r.tput[2]),
        allo_tpj: mean(&|r| r.tpj[3] / r.tpj[2]),
    }
}

/// Fig. 7 as CSV (for external plotting).
pub fn fig7_csv() -> String {
    let data = fig7_data();
    let mut rows = Vec::new();
    for r in &data {
        for (i, sys) in FIG7_SYSTEMS.iter().enumerate() {
            rows.push(vec![r.lp.to_string(), r.ld.to_string(), sys.to_string(),
                           format!("{:.6}", r.e2e[i]), format!("{:.3}", r.tput[i]),
                           format!("{:.6}", r.tpj[i])]);
        }
    }
    csv(&["l_p", "l_d", "system", "e2e_s", "decode_tps", "tokens_per_joule"], &rows)
}

/// The Fig. 8 long-context grid.
pub const FIG8_CONTEXTS: [u64; 6] = [2048, 4096, 8192, 16384, 32768, 65536];

/// Long-context generation length scales with the prompt (summarization /
/// long-form continuation workloads): l_d = ctx/4. This is the regime the
/// paper's Fig. 8 end-to-end claims live in — decode dominates both
/// systems and HMT's linear-vs-quadratic scaling decides the winner.
fn fig8_decode_len(ctx: u64) -> u64 {
    (ctx / 4).max(512)
}

#[derive(Debug)]
pub struct Fig8Row {
    pub ctx: u64,
    /// prefill seconds: [A100 full, U280 full (theoretical), U280+HMT, V80+HMT]
    pub prefill: [f64; 4],
    /// e2e seconds: [A100 BF16, A100 GPTQ, U280+HMT, V80+HMT]
    pub e2e: [f64; 4],
    /// tokens/J: same systems as e2e
    pub tpj: [f64; 4],
}

pub fn fig8_data() -> Vec<Fig8Row> {
    let model = ModelDims::llama32_1b();
    let bf16 = GpuBaseline::a100(model.clone(), GpuMode::Bf16);
    let gptq = GpuBaseline::a100(model.clone(), GpuMode::GptqMarlinInt4);
    let u280 = AcceleratorSystem::u280();
    let v80 = AcceleratorSystem::v80();

    FIG8_CONTEXTS
        .iter()
        .map(|&ctx| {
            let ld = fig8_decode_len(ctx);
            let u_hmt_pre = u280.hmt_prefill_s(ctx);
            let v_hmt_pre = v80.hmt_prefill_s(ctx);
            let u_e2e = u_hmt_pre + u280.reconfig_s + u280.hmt_decode_latency_s(ld);
            let v_e2e = v_hmt_pre + v80.reconfig_s + v80.hmt_decode_latency_s(ld);
            let u_tpj = ld as f64 / (u_e2e * u280.decode.device.avg_power_w);
            let v_tpj = ld as f64 / (v_e2e * v80.decode.device.avg_power_w);
            Fig8Row {
                ctx,
                prefill: [bf16.prefill_latency_s(ctx),
                          u280.prefill.analytic_latency_s(ctx), u_hmt_pre, v_hmt_pre],
                e2e: [bf16.e2e_latency_s(ctx, ld), gptq.e2e_latency_s(ctx, ld), u_e2e, v_e2e],
                tpj: [bf16.tokens_per_joule(ctx, ld), gptq.tokens_per_joule(ctx, ld),
                      u_tpj, v_tpj],
            }
        })
        .collect()
}

/// Fig. 8 rendered with the headline HMT gains.
pub fn fig8() -> String {
    let data = fig8_data();
    let mut out = String::new();

    let rows: Vec<Vec<String>> = data
        .iter()
        .map(|r| vec![
            r.ctx.to_string(),
            fmt_secs(r.prefill[0]), fmt_secs(r.prefill[1]), fmt_secs(r.prefill[2]),
            fmt_secs(r.prefill[3]),
            fmt_ratio(r.prefill[1] / r.prefill[2]),
        ])
        .collect();
    out.push_str(&table(
        "Fig. 8a — long-context prefill latency (paper: HMT cuts U280 prefill up to 23.23×)",
        &["Context", "A100 full", "U280 full(theor.)", "U280+HMT", "V80+HMT", "HMT gain"],
        &rows,
    ));
    out.push('\n');

    let rows: Vec<Vec<String>> = data
        .iter()
        .map(|r| vec![
            r.ctx.to_string(),
            fmt_secs(r.e2e[0]), fmt_secs(r.e2e[1]), fmt_secs(r.e2e[2]), fmt_secs(r.e2e[3]),
            fmt_ratio(r.e2e[0] / r.e2e[2]), fmt_ratio(r.e2e[0] / r.e2e[3]),
        ])
        .collect();
    out.push_str(&table(
        "Fig. 8b — long-context end-to-end latency (l_d = ctx/4; paper: U280 1.10×, V80 3.70×)",
        &["Context", "A100 BF16", "A100 GPTQ", "U280+HMT", "V80+HMT", "U280 gain", "V80 gain"],
        &rows,
    ));
    out.push('\n');

    let rows: Vec<Vec<String>> = data
        .iter()
        .map(|r| vec![
            r.ctx.to_string(),
            format!("{:.4}", r.tpj[0]), format!("{:.4}", r.tpj[1]),
            format!("{:.4}", r.tpj[2]), format!("{:.4}", r.tpj[3]),
            fmt_ratio(r.tpj[2] / r.tpj[0]), fmt_ratio(r.tpj[3] / r.tpj[0]),
        ])
        .collect();
    out.push_str(&table(
        "Fig. 8c — long-context energy efficiency (paper: up to 5.21× U280 / 6.27× V80 vs BF16)",
        &["Context", "A100 BF16", "A100 GPTQ", "U280+HMT", "V80+HMT", "U280 gain", "V80 gain"],
        &rows,
    ));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig7_headline_shape_matches_paper() {
        let h = fig7_headline();
        // who-wins must match; factors within loose bands around the paper
        assert!(h.u280_e2e > 1.0, "U280 must beat A100 BF16 E2E: {}", h.u280_e2e);
        assert!(h.u280_tput > 1.2, "decode tput ratio {}", h.u280_tput);
        assert!(h.u280_tpj > 2.0, "tokens/J ratio {}", h.u280_tpj);
        assert!(h.v80_e2e > 2.5 && h.v80_tput > 4.0 && h.v80_tpj > 2.5,
                "V80 ratios: {} {} {}", h.v80_e2e, h.v80_tput, h.v80_tpj);
        assert!(h.allo_e2e > 1.1 && h.allo_tput > 1.1,
                "Allo ratios: {} {}", h.allo_e2e, h.allo_tput);
    }

    #[test]
    fn fig7_gpu_wins_prefill_heavy_short_decode() {
        // paper: GPU has a clear advantage at [1024, 256]-style workloads
        let data = fig7_data();
        let r = data.iter().find(|r| r.lp == 1024 && r.ld == 256).unwrap();
        // A100 prefill advantage shows in E2E at short decode: ratio near 1
        let ratio = r.e2e[0] / r.e2e[3];
        assert!(ratio < 1.3, "FPGA should not dominate short-decode: {ratio}");
    }

    #[test]
    fn fig8_hmt_prefill_gain_grows_with_context() {
        let data = fig8_data();
        let g0 = data[0].prefill[1] / data[0].prefill[2];
        let gn = data.last().unwrap().prefill[1] / data.last().unwrap().prefill[2];
        assert!(gn > g0, "HMT gain must grow with context: {g0} → {gn}");
        assert!(gn > 10.0, "64K HMT gain = {gn} (paper 23.23×)");
    }

    #[test]
    fn fig8_hmt_restores_fpga_advantage() {
        let data = fig8_data();
        let last = data.last().unwrap();
        assert!(last.e2e[2] < last.e2e[0], "U280+HMT must beat A100 at 64K");
        assert!(last.tpj[2] / last.tpj[0] > 2.0, "energy gain at 64K");
    }

    #[test]
    fn figures_render() {
        assert!(fig1().contains("Hybrid"));
        assert!(fig2().contains("Decode"));
        assert!(fig6().contains("Linear"));
        assert!(fig7_csv().lines().count() > 40);
    }
}
