//! # FlexLLM (reproduction) — composable library for stage-customized hybrid
//! LLM accelerator design.
//!
//! This crate is the L3 layer of the three-layer reproduction (see
//! `DESIGN.md`): it contains
//!
//! * the **HLS module-template library simulator** ([`hls`]) — the paper's
//!   composable kernel/quant libraries with cycle, resource, bandwidth and
//!   dataflow models (the FPGA substrate we cannot run is simulated here);
//! * the **stage-customized architectures** ([`arch`]) for prefill, decode,
//!   the HMT plug-in, and the temporal/spatial baselines;
//! * the **design-space explorer** ([`dse`]) tuning TP/WP/BP under resource
//!   and bandwidth constraints (the paper's ILP);
//! * the **GPU roofline baselines** ([`gpu_model`]) for the A100
//!   comparisons;
//! * the **PJRT runtime** ([`runtime`]) that loads the AOT-compiled JAX /
//!   Pallas artifacts (HLO text) and executes real quantized-model
//!   numerics on CPU;
//! * the **serving coordinator** ([`coordinator`]) — router,
//!   iteration-level continuous-batching scheduler, pluggable execution
//!   backends (PJRT / mock / pipeline-sim-modeled), per-lane KV pool,
//!   HMT segment driver;
//! * the **evaluation harness** ([`eval`]) regenerating every table and
//!   figure of the paper;
//! * the **verify subsystem** ([`verify`]) — shared invariant
//!   predicates, a bounded exhaustive model checker for the KV
//!   page/refcount/migration state machine, and the architectural lint
//!   gate.

// Crate-wide architecture gates (ISSUE 9; `verify::archlint` carries
// the rules the compiler cannot express). Every public type must be
// printable — counterexamples and violation reports have to show the
// state they indict.
#![forbid(unsafe_code)]
#![warn(missing_debug_implementations)]
// Curated hygiene subset (kept deliberately small; each lint is
// all-clean today and cheap to keep clean):
#![warn(clippy::dbg_macro)]
#![warn(clippy::todo)]
#![warn(clippy::unimplemented)]
#![warn(clippy::macro_use_imports)]
#![warn(clippy::mut_mut)]

/// In-tree `anyhow` replacement (the offline build has no external
/// dependencies — see `util::error`). The module keeps the `anyhow`
/// name so call sites read identically to the real crate: in-crate
/// code imports `use crate::anyhow::{anyhow, Result};`, external
/// consumers (examples, tests) `use flexllm::anyhow::...`.
pub mod anyhow {
    pub use crate::util::error::{Context, Error, Result};
    pub use crate::{__flexllm_anyhow as anyhow, __flexllm_bail as bail};
}

pub use crate::{__flexllm_anyhow as anyhow, __flexllm_bail as bail};

pub mod arch;
pub mod config;
pub mod coordinator;
pub mod dse;
pub mod eval;
pub mod gpu_model;
pub mod hls;
pub mod quant;
pub mod report;
pub mod runtime;
pub mod util;
pub mod verify;

pub use config::{DeviceConfig, ModelDims, Precision};
