//! Bounded exhaustive model checker for the KV page / refcount /
//! migration state machine (ISSUE 9 tentpole, layer 2).
//!
//! The checker drives the REAL [`Scheduler`]+[`KvPool`] spine — through
//! [`Engine`]`<`[`MockBackend`]`>`, exactly the stack the tier-1 suites
//! exercise — over EVERY interleaving of a bounded decision space and
//! asserts the layer-1 predicates ([`super::invariants`]) after every
//! action. Nothing here is a simulation of the coordinator: a state the
//! checker reaches is a state production code can reach.
//!
//! **Decision space.** One episode serves a fixed 3-request workload
//! (crafted so prefix sharing, partial-page COW forks and page-boundary
//! divergence all occur) on 1 unified shard or a prefill+decode pair.
//! At each macro-step the explorer chooses among the enabled actions:
//!
//! * `submit(i)` — hand request `i` to the admitting shard (arrival
//!   order is explored, not fixed);
//! * `migrate` — drain the prefill specialist's warm lanes into the
//!   decode shard (migration timing is explored);
//! * `tick(s)` — one `Engine::step` on shard `s` (chunk boundaries,
//!   growth, preemption and completion timing are explored).
//!
//! The search is an odometer DFS over the first
//! [`McBudget::branch_depth`] choice points; deeper decisions take the
//! first enabled action, so every explored prefix still runs to drain.
//! Episodes are deterministic (the spine's only clock feeds metrics,
//! never decisions), which is what makes counterexample traces
//! replayable: a trace is just the choice indices taken.
//!
//! **Stutter pruning.** A `tick` that provably changed nothing (the
//! shard's state digest is unchanged) parks that shard's tick until its
//! digest moves again — a stuttering action can be dropped from any
//! interleaving without losing reachable states, and pruning it keeps
//! the tree finite while a prefill specialist waits for migration.
//!
//! **Verdicts.** Every action is followed by the full predicate set
//! (`check_sched` per shard, cross-shard [`request_aliasing`], the
//! [`StreamLog`] exactly-once checks) plus the stream oracle: each
//! completion's bytes must equal [`MockBackend::expected_tokens`] (or
//! the quantized stream under an Int8 codec). The first violation stops
//! the episode; the trace is then greedily minimized (drop one decision
//! at a time while the SAME invariant still fires) into a
//! [`Counterexample`] whose `replay` spec reproduces it exactly.
//!
//! [`Scheduler`]: crate::coordinator::Scheduler
//! [`KvPool`]: crate::coordinator::KvPool
//! [`Engine`]: crate::coordinator::Engine
//! [`MockBackend`]: crate::coordinator::MockBackend
//! [`request_aliasing`]: super::invariants::request_aliasing
//! [`StreamLog`]: super::invariants::StreamLog
//! [`MockBackend::expected_tokens`]: crate::coordinator::MockBackend::expected_tokens

use std::collections::hash_map::DefaultHasher;
use std::collections::{HashMap, HashSet};
use std::hash::{Hash, Hasher};

use crate::anyhow::{anyhow, Result};
use crate::coordinator::{Engine, FrontDoorConfig, GenRequest, KvLayout,
                         MockBackend, PageCodec, PoolSnapshot, PrefillPolicy,
                         RequestPhase, ReservationPolicy, ShardRole, Slo,
                         SloClass};

use super::invariants::{self, StreamLog, Violation};

// ---------------------------------------------------------------------------
// Fixed geometry: small enough to explore exhaustively, rich enough
// that sharing, COW, growth, preemption and migration all occur.
// ---------------------------------------------------------------------------

const VOCAB: usize = 64;
const LANES: usize = 2;
const PREFILL: usize = 8;
const MAX_SEQ: usize = 16;
const PAGE_LEN: usize = 4;
/// Unified / prefill-shard pool: 7 pages. An upfront lane reserves 4
/// (`max_seq / page_len`), so the second admission stalls at 3 free —
/// exactly the off-by-one a stale free-page report (the
/// `StaleFreeReport` mutant) turns into silent page aliasing.
const PAGES_TIGHT: usize = 7;
/// Decode-shard pool: 8 pages = 2 lanes × 4, so both lanes can hold
/// imported upfront reservations at once.
const PAGES_DECODE: usize = 8;

/// The fixed workload. Prompts are 2 pages; B shares A's first page and
/// diverges mid-page (a partial-page COW fork when enabled), C diverges
/// exactly at the page boundary (full-page sharing, no fork). On a
/// front-door cell request 0 is stamped Interactive, so the
/// never-shed-Interactive discipline is part of the explored space.
fn workload(front: FrontMode) -> Vec<GenRequest> {
    let mut reqs = vec![
        GenRequest::new(0, vec![1, 2, 3, 4, 5, 6, 7, 8], 3),
        GenRequest::new(1, vec![1, 2, 3, 4, 5, 6, 7, 9], 2),
        GenRequest::new(2, vec![1, 2, 3, 4, 9, 9, 9, 9], 2),
    ];
    if front != FrontMode::Off {
        reqs[0].slo = Slo::interactive();
    }
    reqs
}

// ---------------------------------------------------------------------------
// Configuration matrix and exploration budget
// ---------------------------------------------------------------------------

/// Front-door paths a cell drives through the episode (ISSUE 10).
/// `Off` on the base 16 cells keeps their state spaces — and their
/// committed replay traces — exactly the PR 9 behavior.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FrontMode {
    /// No front door: every submission goes straight to shard 0.
    Off,
    /// Load-shed at submit: Batch submissions past the watermark are
    /// rejected (recorded, never owed a stream); request 0 is stamped
    /// Interactive and must never shed.
    Shed,
    /// Cross-shard stealing: a `steal` action moves the youngest
    /// queued request from shard 0 to the idle twin shard.
    Steal,
    /// Shedding and stealing together.
    ShedSteal,
}

/// One cell of the checked matrix: {Upfront, Lazy} × {prefix sharing
/// on, off} × {1 unified shard, prefill+decode pair, unified twin} ×
/// {Fp16, Int8Sym} × front-door mode.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct McConfig {
    pub name: &'static str,
    pub reserve: ReservationPolicy,
    pub share: bool,
    pub disagg: bool,
    pub codec: PageCodec,
    /// Which front-door paths the episode's submissions run through.
    pub front: FrontMode,
    /// Two UNIFIED shards (the steal topology: submissions land on
    /// shard 0, stealing is the only road to shard 1). Exclusive with
    /// `disagg`.
    pub twin: bool,
}

impl McConfig {
    /// The [`FrontDoorConfig`] this cell's episode submits through. The
    /// 0.5 watermark on the 7-page pool (= 4 pages after ceil) is
    /// crossed by the SECOND queued upfront reservation, so shed and
    /// no-shed orders both exist inside the explored tree.
    fn front_door(&self) -> FrontDoorConfig {
        match self.front {
            FrontMode::Off => FrontDoorConfig::default(),
            FrontMode::Shed => FrontDoorConfig::on().with_shed_watermark(0.5),
            FrontMode::Steal => FrontDoorConfig::on().with_steal(true),
            FrontMode::ShedSteal => FrontDoorConfig::on()
                .with_shed_watermark(0.5)
                .with_steal(true),
        }
    }
}

/// All 20 checked configurations, in a stable order: the 16 PR 9 cells
/// (front door off, byte-identical state spaces) plus 4 front-door
/// cells. The names are the replay keys — traces cite them, so they
/// never change.
pub fn matrix() -> Vec<McConfig> {
    const NAMES: [&str; 16] = [
        "upfront-noshare-unified-fp16", "upfront-noshare-unified-int8",
        "upfront-noshare-disagg-fp16", "upfront-noshare-disagg-int8",
        "upfront-share-unified-fp16", "upfront-share-unified-int8",
        "upfront-share-disagg-fp16", "upfront-share-disagg-int8",
        "lazy-noshare-unified-fp16", "lazy-noshare-unified-int8",
        "lazy-noshare-disagg-fp16", "lazy-noshare-disagg-int8",
        "lazy-share-unified-fp16", "lazy-share-unified-int8",
        "lazy-share-disagg-fp16", "lazy-share-disagg-int8",
    ];
    let mut out = Vec::new();
    let mut names = NAMES.iter();
    for reserve in [ReservationPolicy::Upfront, ReservationPolicy::Lazy] {
        for share in [false, true] {
            for disagg in [false, true] {
                for codec in [PageCodec::Fp16, PageCodec::Int8Sym] {
                    let name = names.next().expect("16 names for 16 cells");
                    out.push(McConfig { name, reserve, share, disagg, codec,
                                        front: FrontMode::Off, twin: false });
                }
            }
        }
    }
    out.push(McConfig {
        name: "frontdoor-shed-unified-fp16",
        reserve: ReservationPolicy::Upfront, share: false, disagg: false,
        codec: PageCodec::Fp16, front: FrontMode::Shed, twin: false,
    });
    out.push(McConfig {
        name: "frontdoor-shed-share-unified-int8",
        reserve: ReservationPolicy::Upfront, share: true, disagg: false,
        codec: PageCodec::Int8Sym, front: FrontMode::Shed, twin: false,
    });
    out.push(McConfig {
        name: "frontdoor-steal-twin-fp16",
        reserve: ReservationPolicy::Upfront, share: false, disagg: false,
        codec: PageCodec::Fp16, front: FrontMode::Steal, twin: true,
    });
    out.push(McConfig {
        name: "frontdoor-shedsteal-twin-lazy-fp16",
        reserve: ReservationPolicy::Lazy, share: false, disagg: false,
        codec: PageCodec::Fp16, front: FrontMode::ShedSteal, twin: true,
    });
    out
}

/// Look a matrix cell up by its replay name.
pub fn config_by_name(name: &str) -> Option<McConfig> {
    matrix().into_iter().find(|c| c.name == name)
}

/// Exploration bounds. The search is exhaustive over the first
/// `branch_depth` decisions of every episode; the remaining caps are
/// backstops that turn runaway exploration into a hard error instead
/// of a hang.
#[derive(Debug, Clone, Copy)]
pub struct McBudget {
    /// Choice points explored exhaustively per episode (deeper
    /// decisions take the first enabled action).
    pub branch_depth: usize,
    /// Macro-steps per episode before it is declared stalled (a
    /// violation: the machine must always drain).
    pub max_steps: usize,
    /// Episodes per configuration before the run errors out.
    pub max_interleavings: usize,
}

impl Default for McBudget {
    fn default() -> Self {
        McBudget { branch_depth: 6, max_steps: 200, max_interleavings: 200_000 }
    }
}

// ---------------------------------------------------------------------------
// Reports and counterexamples
// ---------------------------------------------------------------------------

/// A minimized, replayable witness of one invariant violation.
#[derive(Debug, Clone)]
pub struct Counterexample {
    /// Matrix cell the violation occurred in.
    pub config: String,
    /// Choice indices of the minimized trace (the replay spec's body).
    pub trace: Vec<usize>,
    /// Human-readable action labels of the full violating episode.
    pub labels: Vec<String>,
    /// The first predicate that fired.
    pub violation: Violation,
}

impl Counterexample {
    /// The `flexllm verify --replay` spec reproducing this episode.
    pub fn replay_spec(&self) -> String {
        let trace: Vec<String> =
            self.trace.iter().map(ToString::to_string).collect();
        format!("{}:{}", self.config, trace.join(","))
    }
}

impl std::fmt::Display for Counterexample {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        writeln!(f, "counterexample in config {} (replay \"{}\"):",
                 self.config, self.replay_spec())?;
        for (i, label) in self.labels.iter().enumerate() {
            writeln!(f, "  step {i:>2}: {label}")?;
        }
        write!(f, "  {}", self.violation)
    }
}

/// The verdict for one matrix cell.
#[derive(Debug, Clone)]
pub struct McReport {
    pub config: String,
    /// Interleavings fully explored.
    pub interleavings: usize,
    /// Distinct post-action state digests observed.
    pub unique_states: usize,
    /// First violation found, already minimized (`None` = clean).
    pub violation: Option<Counterexample>,
}

// ---------------------------------------------------------------------------
// Episode: one deterministic run through the bounded decision space
// ---------------------------------------------------------------------------

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Action {
    Submit(usize),
    Migrate,
    Steal,
    Tick(usize),
}

impl Action {
    fn label(self) -> String {
        match self {
            Action::Submit(i) => format!("submit(req {i})"),
            Action::Migrate => "migrate(prefill -> decode)".to_string(),
            Action::Steal => "steal(shard 0 -> shard 1)".to_string(),
            Action::Tick(s) => format!("tick(shard {s})"),
        }
    }
}

/// What one episode did: the recorded choice points (for the
/// odometer), the action labels, the digests it visited and its
/// verdict.
struct EpisodeOut {
    decisions: Vec<(usize, usize)>,
    labels: Vec<String>,
    digests: Vec<u64>,
    violation: Option<Violation>,
}

struct Episode {
    shards: Vec<Engine<MockBackend>>,
    reqs: Vec<GenRequest>,
    submitted: Vec<bool>,
    log: StreamLog,
    /// Per-request event-stream accumulation (token per index).
    streams: HashMap<u64, Vec<i32>>,
    /// Shard digests whose tick last proved to be a no-op; the tick
    /// stays parked until the digest moves (stutter pruning).
    parked: Vec<Option<u64>>,
    codec: PageCodec,
    /// The cell's front door, applied at every `submit`.
    front: FrontDoorConfig,
    /// Requests the front door rejected — marked submitted (the action
    /// is consumed) but never owed a token stream.
    shed: Vec<bool>,
}

fn build_shards(cfg: &McConfig) -> Vec<Engine<MockBackend>> {
    let mk = |pages: usize| {
        let mut b = MockBackend::paged(LANES, PREFILL, MAX_SEQ, VOCAB,
                                       PAGE_LEN, pages);
        if cfg.reserve == ReservationPolicy::Lazy {
            b = b.with_table_growth();
        }
        if cfg.codec == PageCodec::Int8Sym {
            b = b.with_kv_quant(PageCodec::Int8Sym);
        }
        b
    };
    // two-chunk prefill: a lane stays `Prefilling` across ticks, so
    // chunk boundaries are real interleaving points
    let policy = PrefillPolicy::Chunked { chunk_len: PAGE_LEN,
                                          decode_priority: false };
    if cfg.disagg {
        vec![
            Engine::with_reservation(mk(PAGES_TIGHT), policy, KvLayout::Paged,
                                     cfg.reserve)
                .with_role(ShardRole::Prefill)
                .with_shard_id(0)
                .with_prefix_share(cfg.share),
            Engine::with_reservation(mk(PAGES_DECODE), policy, KvLayout::Paged,
                                     cfg.reserve)
                .with_role(ShardRole::Decode)
                .with_shard_id(1)
                .with_prefix_share(cfg.share),
        ]
    } else if cfg.twin {
        // two UNIFIED shards for the steal topology: submissions land
        // on shard 0; stealing is the only road onto shard 1
        (0..2)
            .map(|i| {
                Engine::with_reservation(mk(PAGES_TIGHT), policy,
                                         KvLayout::Paged, cfg.reserve)
                    .with_shard_id(i)
                    .with_prefix_share(cfg.share)
            })
            .collect()
    } else {
        vec![Engine::with_reservation(mk(PAGES_TIGHT), policy, KvLayout::Paged,
                                      cfg.reserve)
            .with_shard_id(0)
            .with_prefix_share(cfg.share)]
    }
}

impl Episode {
    fn new(cfg: &McConfig) -> Self {
        let shards = build_shards(cfg);
        let reqs = workload(cfg.front);
        let parked = vec![None; shards.len()];
        Episode {
            submitted: vec![false; reqs.len()],
            log: StreamLog::default(),
            streams: HashMap::new(),
            parked,
            codec: cfg.codec,
            front: cfg.front_door(),
            shed: vec![false; reqs.len()],
            shards,
            reqs,
        }
    }

    /// Pool-wide congestion snapshot for the shed decision: pages in
    /// use plus queued demand over admitting shards — the same signal
    /// the Router's admission gate and the open-loop harness read.
    fn pool_snapshot(&self) -> PoolSnapshot {
        let mut total = 0usize;
        let mut queued = 0usize;
        for sh in &self.shards {
            if !sh.role().accepts_new_requests() {
                continue;
            }
            let t = sh.scheduler.total_pages();
            total += t;
            queued += t.saturating_sub(sh.scheduler.free_pages())
                + sh.scheduler.queued_pages();
        }
        PoolSnapshot { total_pages: total, queued_pages: queued }
    }

    fn shard_digest(&self, s: usize) -> u64 {
        let mut h = DefaultHasher::new();
        let sched = &self.shards[s].scheduler;
        sched.free_pages().hash(&mut h);
        for lane in 0..sched.lanes() {
            sched.prompt_owner(lane).hash(&mut h);
            if let Ok(table) = sched.page_table(lane) {
                table.hash(&mut h);
            }
            sched.lane_pos(lane).hash(&mut h);
            match sched.phase(lane) {
                None => 0usize.hash(&mut h),
                Some(RequestPhase::Prefilling { next_chunk }) => {
                    (1usize, next_chunk).hash(&mut h);
                }
                Some(RequestPhase::Decoding) => 2usize.hash(&mut h),
            }
        }
        for p in 0..sched.total_pages() {
            sched.page_refcount(p as u32).hash(&mut h);
        }
        sched.queued_ids().hash(&mut h);
        let mut retained = sched.prefix_retained_pages();
        retained.sort_unstable();
        retained.hash(&mut h);
        h.finish()
    }

    fn digest(&self) -> u64 {
        let mut h = DefaultHasher::new();
        for s in 0..self.shards.len() {
            self.shard_digest(s).hash(&mut h);
        }
        self.submitted.hash(&mut h);
        self.shed.hash(&mut h);
        self.log.completed.hash(&mut h);
        h.finish()
    }

    /// Lanes on the prefill specialist waiting in `Decoding` phase.
    fn migratable(&self) -> usize {
        let donor = &self.shards[0];
        if donor.role() != ShardRole::Prefill {
            return 0;
        }
        (0..donor.scheduler.lanes())
            .filter(|&l| donor.scheduler.phase(l)
                    == Some(RequestPhase::Decoding))
            .count()
    }

    /// Enabled actions, in a stable order. `migrate` precedes `tick` so
    /// the all-default path (choice 0 everywhere) migrates promptly —
    /// the deterministic completion of every branch still drains.
    fn enabled(&self) -> Vec<Action> {
        let mut acts = Vec::new();
        for (i, &done) in self.submitted.iter().enumerate() {
            if !done {
                acts.push(Action::Submit(i));
            }
        }
        let migratable = self.migratable();
        if migratable > 0 {
            // conservative import guard: enough free lanes AND a full
            // upfront reservation per lane, so take_migratable (which
            // drains every warm lane at once) can never strand one
            let dest = &self.shards[1].scheduler;
            let free_lanes = dest.lanes() - dest.active();
            let pages_per_lane = MAX_SEQ / PAGE_LEN;
            if free_lanes >= migratable
                && dest.free_pages() >= migratable * pages_per_lane
            {
                acts.push(Action::Migrate);
            }
        }
        // stealing mirrors the coordinator's gate: receiver idle and
        // admitting, donor holding queued (never prefilled) work
        if self.front.enabled
            && self.front.steal
            && self.shards.len() > 1
            && self.shards[1].role() == ShardRole::Unified
            && !self.shards[1].has_work()
            && self.shards[0].scheduler.stealable_queued() > 0
        {
            acts.push(Action::Steal);
        }
        for s in 0..self.shards.len() {
            if self.shards[s].has_work()
                && self.parked[s] != Some(self.shard_digest(s))
            {
                acts.push(Action::Tick(s));
            }
        }
        acts
    }

    /// Execute one action; returns violations observed applying it.
    fn apply(&mut self, act: Action) -> Result<Vec<Violation>> {
        let mut out = Vec::new();
        match act {
            Action::Submit(i) => {
                let req = self.reqs[i].clone();
                self.submitted[i] = true;
                if self.front.shed(&req.slo, self.pool_snapshot()).is_some() {
                    if req.slo.class == SloClass::Interactive {
                        out.push(Violation {
                            invariant: "shed-discipline",
                            detail: format!(
                                "Interactive request {} was shed", req.id),
                        });
                    }
                    self.shed[i] = true;
                    return Ok(out);
                }
                self.log.submitted.push(req.id);
                self.shards[0].submit(req)?;
            }
            Action::Steal => {
                if let Some((_, req)) =
                    self.shards[0].scheduler.steal_youngest_queued()
                {
                    self.shards[1].submit(req)?;
                } else {
                    out.push(Violation {
                        invariant: "steal-discipline",
                        detail: "steal enabled with nothing stealable"
                            .to_string(),
                    });
                }
            }
            Action::Migrate => {
                let taken = self.shards[0].take_migratable();
                self.log.migrations_taken += taken.len();
                for m in taken {
                    if !self.shards[1].can_import(&m) {
                        out.push(Violation {
                            invariant: "migration-balance",
                            detail: format!(
                                "decode shard refused request {} after the \
                                 import guard admitted the batch", m.req.id),
                        });
                        return Ok(out);
                    }
                    self.shards[1].import_migrated(m)?;
                    self.log.migrations_imported += 1;
                }
            }
            Action::Tick(s) => {
                let before = self.shard_digest(s);
                let report = self.shards[s].step()?;
                for ev in &report.events {
                    let stream = self.streams.entry(ev.id).or_default();
                    if ev.index != stream.len() {
                        out.push(Violation {
                            invariant: "stream-identity",
                            detail: format!(
                                "request {} emitted index {} after {} tokens \
                                 (gap or replay)", ev.id, ev.index,
                                stream.len()),
                        });
                    }
                    stream.push(ev.token);
                }
                for (_, result) in &report.completed {
                    self.log.completed.push(result.id);
                    let want = self.oracle(result.id);
                    if result.tokens != want {
                        out.push(Violation {
                            invariant: "stream-identity",
                            detail: format!(
                                "request {} completed with {:?}, expected \
                                 {:?}", result.id, result.tokens, want),
                        });
                    }
                }
                if self.shard_digest(s) == before {
                    self.parked[s] = Some(before);
                } else {
                    self.parked[s] = None;
                }
            }
        }
        Ok(out)
    }

    /// The mock stream a request must produce, under the active codec.
    fn oracle(&self, id: u64) -> Vec<i32> {
        let req = &self.reqs[id as usize];
        let n = req.max_new_tokens;
        match self.codec {
            PageCodec::Fp16 =>
                MockBackend::expected_tokens(&req.prompt, n, VOCAB),
            PageCodec::Int8Sym =>
                MockBackend::expected_tokens_quant(&req.prompt, n, VOCAB,
                                                   PAGE_LEN),
        }
    }

    /// The full predicate set over the current state.
    fn check(&self) -> Vec<Violation> {
        let mut out = Vec::new();
        for shard in &self.shards {
            let sid = shard.shard_id();
            for v in invariants::check_sched(&shard.scheduler) {
                out.push(Violation {
                    invariant: v.invariant,
                    detail: format!("shard {sid}: {}", v.detail),
                });
            }
            if shard.scheduler.kv_corruptions() > 0 {
                out.push(Violation {
                    invariant: "kv-corruption",
                    detail: format!(
                        "shard {sid}: pool counted {} corruption events",
                        shard.scheduler.kv_corruptions()),
                });
            }
        }
        invariants::request_aliasing(
            self.shards.iter().map(|e| &e.scheduler), &mut out);
        self.log.check_partial(&mut out);
        out
    }
}

/// Run one episode, consuming `trace` at the first `branch_depth`
/// choice points (missing entries and all deeper decisions take the
/// first enabled action).
fn run_episode(cfg: &McConfig, budget: &McBudget, trace: &[usize])
    -> Result<EpisodeOut>
{
    let mut ep = Episode::new(cfg);
    let mut out = EpisodeOut {
        decisions: Vec::new(),
        labels: Vec::new(),
        digests: Vec::new(),
        violation: None,
    };
    for _ in 0..budget.max_steps {
        let acts = ep.enabled();
        if acts.is_empty() {
            break;
        }
        let k = out.decisions.len();
        let choice = if k < budget.branch_depth {
            // clamp: minimization candidates may carry an index the
            // shorter tree no longer offers
            let c = trace.get(k).copied().unwrap_or(0).min(acts.len() - 1);
            out.decisions.push((c, acts.len()));
            c
        } else {
            0
        };
        let act = acts[choice];
        out.labels.push(act.label());
        let mut violations = ep.apply(act)?;
        if let Action::Submit(i) = act {
            if ep.shed[i] {
                // make shed decisions visible in counterexample traces
                *out.labels.last_mut().expect("label just pushed") =
                    format!("submit(req {i}) -> shed");
            }
        }
        violations.extend(ep.check());
        out.digests.push(ep.digest());
        if let Some(v) = violations.into_iter().next() {
            out.violation = Some(v);
            return Ok(out);
        }
    }
    let outstanding: Vec<u64> = ep.log.submitted.iter().copied()
        .filter(|id| !ep.log.completed.contains(id))
        .collect();
    if !outstanding.is_empty() || ep.submitted.iter().any(|&s| !s) {
        out.violation = Some(Violation {
            invariant: "drain",
            detail: format!(
                "episode ended after {} steps with requests {outstanding:?} \
                 outstanding", out.labels.len()),
        });
        return Ok(out);
    }
    let mut drained = Vec::new();
    ep.log.check_drained(&mut drained);
    for (i, &shed) in ep.shed.iter().enumerate() {
        if shed && ep.streams.contains_key(&(i as u64)) {
            drained.push(Violation {
                invariant: "shed-discipline",
                detail: format!(
                    "request {i} was shed at the front door but streamed \
                     tokens anyway"),
            });
        }
    }
    for (id, got) in &ep.streams {
        let want = ep.oracle(*id);
        if *got != want {
            drained.push(Violation {
                invariant: "stream-identity",
                detail: format!(
                    "request {id} streamed {got:?}, expected {want:?}"),
            });
        }
    }
    out.violation = drained.into_iter().next();
    Ok(out)
}

// ---------------------------------------------------------------------------
// The explorer: odometer DFS + greedy trace minimization
// ---------------------------------------------------------------------------

/// Exhaustively explore one matrix cell. A violation is returned
/// minimized; `Err` means the checker itself failed (backend refusal,
/// interleaving budget exhausted) — never a property verdict.
pub fn check_config(cfg: &McConfig, budget: &McBudget) -> Result<McReport> {
    let mut trace: Vec<usize> = Vec::new();
    let mut interleavings = 0usize;
    let mut states: HashSet<u64> = HashSet::new();
    loop {
        let out = run_episode(cfg, budget, &trace)?;
        interleavings += 1;
        states.extend(out.digests.iter().copied());
        if let Some(v) = out.violation {
            let ce = minimize(cfg, budget, &out.decisions, v)?;
            return Ok(McReport {
                config: cfg.name.to_string(),
                interleavings,
                unique_states: states.len(),
                violation: Some(ce),
            });
        }
        if interleavings >= budget.max_interleavings {
            return Err(anyhow!(
                "config {}: interleaving budget {} exhausted before the \
                 bounded space was covered", cfg.name,
                budget.max_interleavings));
        }
        // advance the odometer: bump the deepest decision that still
        // has an untaken alternative, drop everything after it
        let mut decisions = out.decisions;
        loop {
            match decisions.last_mut() {
                None => {
                    return Ok(McReport {
                        config: cfg.name.to_string(),
                        interleavings,
                        unique_states: states.len(),
                        violation: None,
                    });
                }
                Some((choice, alts)) if *choice + 1 < *alts => {
                    *choice += 1;
                    break;
                }
                Some(_) => {
                    decisions.pop();
                }
            }
        }
        trace = decisions.iter().map(|&(c, _)| c).collect();
    }
}

/// Greedily shrink a violating trace: drop one decision at a time as
/// long as the SAME invariant still fires, then strip trailing
/// default choices (a missing entry already means "first enabled").
fn minimize(cfg: &McConfig, budget: &McBudget, decisions: &[(usize, usize)],
            violation: Violation) -> Result<Counterexample>
{
    let mut trace: Vec<usize> = decisions.iter().map(|&(c, _)| c).collect();
    let mut labels = None;
    let mut shrunk = true;
    while shrunk {
        shrunk = false;
        for i in 0..trace.len() {
            let mut candidate = trace.clone();
            candidate.remove(i);
            let out = run_episode(cfg, budget, &candidate)?;
            if out.violation.as_ref().map(|v| v.invariant)
                == Some(violation.invariant)
            {
                trace = candidate;
                labels = Some(out.labels);
                shrunk = true;
                break;
            }
        }
    }
    while trace.last() == Some(&0) {
        trace.pop();
    }
    let labels = match labels {
        Some(l) => l,
        None => run_episode(cfg, budget, &trace)?.labels,
    };
    Ok(Counterexample {
        config: cfg.name.to_string(),
        trace,
        labels,
        violation,
    })
}

/// Explore the full 16-cell matrix; reports come back in matrix order.
pub fn check_all(budget: &McBudget) -> Result<Vec<McReport>> {
    matrix().iter().map(|cfg| check_config(cfg, budget)).collect()
}

/// Re-run one recorded episode from a `config:choice,choice,...` spec
/// (the body of [`Counterexample::replay_spec`]). Returns the episode's
/// verdict without exploring or minimizing — determinism makes this an
/// exact reproduction.
pub fn replay(spec: &str, budget: &McBudget) -> Result<McReport> {
    let (name, body) = spec.split_once(':')
        .ok_or_else(|| anyhow!("replay spec must be config:i,j,k — got \
                                {spec:?}"))?;
    let cfg = config_by_name(name)
        .ok_or_else(|| anyhow!("unknown config {name:?}; cells are named \
                                <upfront|lazy>-<share|noshare>-\
                                <unified|disagg>-<fp16|int8> plus the \
                                frontdoor-* cells (run `flexllm verify` \
                                for the list)"))?;
    let trace: Vec<usize> = body
        .split(',')
        .filter(|t| !t.trim().is_empty())
        .map(|t| t.trim().parse::<usize>()
             .map_err(|e| anyhow!("bad choice index {t:?}: {e}")))
        .collect::<Result<_>>()?;
    // the replayed trace must be consumable whole, whatever depth the
    // caller's exploration budget says
    let budget = McBudget {
        branch_depth: budget.branch_depth.max(trace.len()),
        ..*budget
    };
    let out = run_episode(&cfg, &budget, &trace)?;
    let violation = out.violation.map(|v| Counterexample {
        config: cfg.name.to_string(),
        trace,
        labels: out.labels,
        violation: v,
    });
    Ok(McReport {
        config: cfg.name.to_string(),
        interleavings: 1,
        unique_states: out.digests.len(),
        violation,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The matrix is 20 distinct, name-addressable cells: the 16-cell
    /// base product plus 4 front-door cells.
    #[test]
    fn matrix_is_complete_and_named() {
        let m = matrix();
        assert_eq!(m.len(), 20);
        let names: HashSet<&str> = m.iter().map(|c| c.name).collect();
        assert_eq!(names.len(), 20, "config names must be unique");
        for cfg in &m {
            assert_eq!(config_by_name(cfg.name), Some(*cfg));
        }
        assert_eq!(m.iter().filter(|c| c.front != FrontMode::Off).count(), 4);
    }

    /// A single all-defaults episode on the simplest cell drains clean:
    /// every request completes, streams match the mock oracle.
    #[test]
    fn default_episode_drains_clean() {
        let cfg = config_by_name("upfront-noshare-unified-fp16")
            .expect("matrix cell exists");
        let budget = McBudget { branch_depth: 0, ..McBudget::default() };
        let out = run_episode(&cfg, &budget, &[]).expect("episode runs");
        assert!(out.violation.is_none(),
                "clean tree must drain without violations: {:?}",
                out.violation);
        assert!(out.labels.iter().any(|l| l.contains("submit")));
    }

    /// The disagg default path actually migrates (the `migrate` action
    /// precedes `tick` in the stable order, so choice-0 paths take it).
    #[test]
    fn default_disagg_episode_migrates() {
        let cfg = config_by_name("upfront-noshare-disagg-fp16")
            .expect("matrix cell exists");
        let budget = McBudget { branch_depth: 0, ..McBudget::default() };
        let out = run_episode(&cfg, &budget, &[]).expect("episode runs");
        assert!(out.violation.is_none(), "clean drain: {:?}", out.violation);
        assert!(out.labels.iter().any(|l| l.contains("migrate")),
                "default disagg path must exercise migration: {:?}",
                out.labels);
    }

    /// The shed cell's default path actually sheds: the tight unified
    /// pool (7 pages, 4-page upfront reservations, watermark 4) rejects
    /// the third Batch submit, and the episode still drains clean.
    #[test]
    fn default_shed_episode_sheds_batch_and_drains() {
        let cfg = config_by_name("frontdoor-shed-unified-fp16")
            .expect("matrix cell exists");
        let budget = McBudget { branch_depth: 0, ..McBudget::default() };
        let out = run_episode(&cfg, &budget, &[]).expect("episode runs");
        assert!(out.violation.is_none(), "clean drain: {:?}", out.violation);
        assert!(out.labels.iter().any(|l| l.contains("-> shed")),
                "default shed path must exercise load-shed: {:?}",
                out.labels);
    }

    /// The steal cell's default path actually steals (the `steal` action
    /// precedes `tick` in the stable order, so choice-0 paths take it).
    #[test]
    fn default_steal_episode_steals_and_drains() {
        let cfg = config_by_name("frontdoor-steal-twin-fp16")
            .expect("matrix cell exists");
        let budget = McBudget { branch_depth: 0, ..McBudget::default() };
        let out = run_episode(&cfg, &budget, &[]).expect("episode runs");
        assert!(out.violation.is_none(), "clean drain: {:?}", out.violation);
        assert!(out.labels.iter().any(|l| l.contains("steal")),
                "default twin path must exercise work stealing: {:?}",
                out.labels);
    }

    /// Replay rejects malformed specs and unknown configs.
    #[test]
    fn replay_spec_parsing_rejects_garbage() {
        let budget = McBudget::default();
        assert!(replay("no-colon", &budget).is_err());
        assert!(replay("not-a-config:0,1", &budget).is_err());
        assert!(replay("upfront-noshare-unified-fp16:zero", &budget).is_err());
    }
}
