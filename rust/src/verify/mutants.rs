//! Compile-time-selected fault injections for the model checker's
//! mutation gate (ISSUE 9 satellite).
//!
//! A model checker that never fires is indistinguishable from one that
//! cannot fire. This module plants three known-fatal bugs in the KV
//! ownership machinery — each a real bug class the serving spine has
//! to defend against — behind the off-by-default `verify-mutants`
//! feature, and the tier-1 `verify_mutants` suite asserts the bounded
//! explorer CATCHES every one of them with a minimized, replayable
//! counterexample:
//!
//! * [`Mutant::SkipSharedRelease`] — [`KvPool::release`] drops the
//!   refcount decrement on a shared page (the COW leak): the page can
//!   never free once its sharers leave.
//! * [`Mutant::DropDonorRelease`] — the donor shard's
//!   [`Scheduler::take_migratable`] forgets to release a migrated
//!   lane's pages: the donor pool leaks every migrated request.
//! * [`Mutant::StaleFreeReport`] — admission reads a stale free-page
//!   count and [`KvPool::alloc`] "satisfies" the shortage with a
//!   duplicate of a live page: two lanes silently alias one physical
//!   page.
//!
//! Without the feature the module compiles down to a `const fn` that
//! returns `false` — every injection site folds away; with the
//! feature, the active mutant is selected at runtime through [`arm`]
//! so one test binary can exercise each fault in turn.
//!
//! [`KvPool::release`]: crate::coordinator::KvPool::release
//! [`KvPool::alloc`]: crate::coordinator::KvPool::alloc
//! [`Scheduler::take_migratable`]: crate::coordinator::Scheduler::take_migratable

/// One plantable fault. The discriminants are stable — counterexample
/// traces name mutants by this id.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Mutant {
    /// Skip the refcount decrement when releasing a shared page.
    SkipSharedRelease = 1,
    /// Donor shard keeps a migrated lane's pages allocated.
    DropDonorRelease = 2,
    /// Admission trusts a stale (+1) free-page report; the allocator
    /// covers the shortage by aliasing a live page.
    StaleFreeReport = 3,
}

#[cfg(feature = "verify-mutants")]
mod armed {
    use std::sync::atomic::{AtomicUsize, Ordering};

    /// 0 = no mutant armed; otherwise `Mutant as usize`.
    static ACTIVE: AtomicUsize = AtomicUsize::new(0);

    /// Select which fault is live (`None` disarms). Tests touching
    /// this shared switch must serialize — see the `verify_mutants`
    /// suite's mutex.
    pub fn arm(m: Option<super::Mutant>) {
        ACTIVE.store(m.map_or(0, |m| m as usize), Ordering::SeqCst);
    }

    /// Whether `m` is the armed fault.
    pub fn active(m: super::Mutant) -> bool {
        ACTIVE.load(Ordering::SeqCst) == m as usize
    }
}

#[cfg(feature = "verify-mutants")]
pub use armed::{active, arm};

/// Without the `verify-mutants` feature no fault can ever be live;
/// the injection sites guard on this constant `false` and fold away.
#[cfg(not(feature = "verify-mutants"))]
pub const fn active(_m: Mutant) -> bool {
    false
}
