//! Shared invariant predicates over KV-pool / scheduler snapshots.
//!
//! One predicate set, three consumers (ISSUE 9): the tier-1 test
//! suites (`tests/sharding.rs` fuzz loop, the `prefix_share` /
//! `disagg` leak checks), the `cfg(debug_assertions)` per-tick probe
//! in [`Engine::step`](crate::coordinator::Engine::step), and the
//! bounded model checker ([`super::mc`]) all call the SAME functions
//! in this module — so the checked contract cannot drift between the
//! fuzzer, the debug build and the exhaustive explorer.
//!
//! The predicates are pure functions over two snapshot traits:
//!
//! * [`PoolView`] — the allocator's own accounting (page counts and
//!   per-page refcounts). Implemented by
//!   [`KvPool`](crate::coordinator::KvPool) directly.
//! * [`SchedView`] — the allocator view PLUS who references each page
//!   (live lane tables, prefix-index retains) and each lane's write
//!   cursor. Implemented by
//!   [`Scheduler`](crate::coordinator::Scheduler) through its public
//!   accessor surface only — the predicates deliberately cannot see
//!   private state, so anything they prove is provable from outside.
//!
//! ## Invariant catalog (see DESIGN.md §15 for rationale)
//!
//! | id                    | statement                                  |
//! |-----------------------|--------------------------------------------|
//! | `page-conservation`   | free + live == total, counted two ways     |
//! | `refcount-consistency`| refcount(p) == #tables(p) + #index(p), ∀p  |
//! | `table-sanity`        | table pages in range, allocated, no dups   |
//! | `cow-write-safety`    | a lane's next write page has refcount 1    |
//! | `request-aliasing`    | a request id lives on at most one shard    |
//! | `completion-exactly-once` | every id completes exactly once        |
//! | `migration-balance`   | lanes taken from donors == lanes imported  |

use std::collections::HashMap;

use crate::coordinator::{KvPool, Scheduler};

/// One failed invariant: which predicate, and a human-readable account
/// of the state that broke it. `Display` renders both.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Violation {
    /// Stable predicate id (the table in the module docs).
    pub invariant: &'static str,
    /// What was observed, with the numbers that disagree.
    pub detail: String,
}

impl std::fmt::Display for Violation {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "[{}] {}", self.invariant, self.detail)
    }
}

/// Allocator-only snapshot: what the pool believes about its pages.
pub trait PoolView {
    fn total_pages(&self) -> usize;
    fn free_pages(&self) -> usize;
    /// Owners of `page`; 0 means the page is on the free list. Must
    /// tolerate any `page < total_pages`.
    fn page_refcount(&self, page: u32) -> u32;
}

/// One occupied lane, as the predicates need to see it.
#[derive(Debug, Clone)]
pub struct LaneSnapshot {
    pub lane: usize,
    /// Bound request id (for violation messages).
    pub id: u64,
    /// Physical pages backing the lane, logical order.
    pub table: Vec<u32>,
    /// Next cache write position (rows).
    pub pos: usize,
}

/// Scheduler-wide snapshot: the pool plus every page referent.
pub trait SchedView: PoolView {
    /// Cache rows per page.
    fn page_len(&self) -> usize;
    /// Every occupied lane's table and write cursor.
    fn lane_snapshots(&self) -> Vec<LaneSnapshot>;
    /// Every page the prefix index holds a retain on (one entry per
    /// retain — multiplicity matters for refcount consistency).
    fn prefix_retained(&self) -> Vec<u32>;
    /// Request ids currently in flight on lanes.
    fn inflight_ids(&self) -> Vec<u64>;
    /// Request ids waiting in the admission queue.
    fn queued_ids(&self) -> Vec<u64>;
}

// ---------------------------------------------------------------------------
// Trait implementations (public accessors only)
// ---------------------------------------------------------------------------

impl PoolView for KvPool {
    fn total_pages(&self) -> usize {
        KvPool::total_pages(self)
    }

    fn free_pages(&self) -> usize {
        KvPool::free_pages(self)
    }

    fn page_refcount(&self, page: u32) -> u32 {
        self.refcount(page)
    }
}

impl PoolView for Scheduler {
    fn total_pages(&self) -> usize {
        Scheduler::total_pages(self)
    }

    fn free_pages(&self) -> usize {
        Scheduler::free_pages(self)
    }

    fn page_refcount(&self, page: u32) -> u32 {
        Scheduler::page_refcount(self, page)
    }
}

impl SchedView for Scheduler {
    fn page_len(&self) -> usize {
        Scheduler::page_len(self)
    }

    fn lane_snapshots(&self) -> Vec<LaneSnapshot> {
        (0..self.lanes())
            .filter_map(|lane| {
                let id = self.prompt_owner(lane)?;
                let table = self.page_table(lane).ok()?.to_vec();
                let pos = self.lane_pos(lane)?;
                Some(LaneSnapshot { lane, id, table, pos })
            })
            .collect()
    }

    fn prefix_retained(&self) -> Vec<u32> {
        self.prefix_retained_pages()
    }

    fn inflight_ids(&self) -> Vec<u64> {
        Scheduler::inflight_ids(self)
    }

    fn queued_ids(&self) -> Vec<u64> {
        Scheduler::queued_ids(self)
    }
}

// ---------------------------------------------------------------------------
// Pool-level predicates
// ---------------------------------------------------------------------------

/// `page-conservation`: the free list and the refcount table must tell
/// the same story — every page is either free (refcount 0) or live
/// (refcount > 0), and the two populations partition the pool. A leak
/// (page unreachable but not free) or a free-list corruption (page on
/// the free list with owners) breaks the partition.
pub fn page_conservation(view: &impl PoolView, out: &mut Vec<Violation>) {
    let total = view.total_pages();
    let free = view.free_pages();
    let live = (0..total as u32).filter(|&p| view.page_refcount(p) > 0).count();
    if free + live != total {
        out.push(Violation {
            invariant: "page-conservation",
            detail: format!(
                "free ({free}) + live-by-refcount ({live}) != total ({total})"),
        });
    }
    if free > total {
        out.push(Violation {
            invariant: "page-conservation",
            detail: format!("free list ({free}) exceeds the pool ({total})"),
        });
    }
}

// ---------------------------------------------------------------------------
// Scheduler-level predicates
// ---------------------------------------------------------------------------

/// `refcount-consistency`: every page's refcount equals the number of
/// live referents — occurrences across lane page tables plus prefix
/// index retains. `refcount > referents` is a leak (the page can never
/// free); `refcount < referents` is a use-after-free in waiting (the
/// page frees while a table still maps it).
pub fn refcount_consistency(view: &impl SchedView, out: &mut Vec<Violation>) {
    let mut expected: HashMap<u32, u32> = HashMap::new();
    for lane in view.lane_snapshots() {
        for &page in &lane.table {
            *expected.entry(page).or_insert(0) += 1;
        }
    }
    for page in view.prefix_retained() {
        *expected.entry(page).or_insert(0) += 1;
    }
    for page in 0..view.total_pages() as u32 {
        let want = expected.get(&page).copied().unwrap_or(0);
        let got = view.page_refcount(page);
        if got != want {
            out.push(Violation {
                invariant: "refcount-consistency",
                detail: format!(
                    "page {page}: refcount {got}, but {want} referents \
                     (lane tables + prefix retains)"),
            });
        }
    }
}

/// `table-sanity`: every mapped page id is in range and allocated, and
/// no lane maps the same physical page twice (two LOGICAL rows of one
/// request aliasing one physical page corrupts the cache silently —
/// sharing is only legal ACROSS lanes).
pub fn table_sanity(view: &impl SchedView, out: &mut Vec<Violation>) {
    let total = view.total_pages();
    for lane in view.lane_snapshots() {
        let mut seen = std::collections::HashSet::new();
        for &page in &lane.table {
            if (page as usize) >= total {
                out.push(Violation {
                    invariant: "table-sanity",
                    detail: format!(
                        "lane {} (request {}): foreign page id {page} \
                         ({total} pages)", lane.lane, lane.id),
                });
                continue;
            }
            if view.page_refcount(page) == 0 {
                out.push(Violation {
                    invariant: "table-sanity",
                    detail: format!(
                        "lane {} (request {}): table maps FREE page {page}",
                        lane.lane, lane.id),
                });
            }
            if !seen.insert(page) {
                out.push(Violation {
                    invariant: "table-sanity",
                    detail: format!(
                        "lane {} (request {}): page {page} mapped twice \
                         in one table", lane.lane, lane.id),
                });
            }
        }
    }
    for page in view.prefix_retained() {
        if (page as usize) >= total || view.page_refcount(page) == 0 {
            out.push(Violation {
                invariant: "table-sanity",
                detail: format!(
                    "prefix index retains a free or foreign page {page}"),
            });
        }
    }
}

/// `cow-write-safety`: the page under a lane's next write position must
/// be PRIVATE (refcount 1). Shared-prefix admission starts the fill
/// cursor past the resident span and partial overlaps fork a
/// copy-on-write page first, so by construction no lane ever has a
/// shared page under its cursor — if one does, the next scatter
/// corrupts every other owner's cache.
pub fn cow_write_safety(view: &impl SchedView, out: &mut Vec<Violation>) {
    let page_len = view.page_len();
    for lane in view.lane_snapshots() {
        let logical = lane.pos / page_len;
        // under lazy reservation the cursor's page may not be allocated
        // yet — nothing to check until growth backs it
        let Some(&page) = lane.table.get(logical) else { continue };
        let refs = view.page_refcount(page);
        if refs > 1 {
            out.push(Violation {
                invariant: "cow-write-safety",
                detail: format!(
                    "lane {} (request {}): next write at row {} lands in \
                     page {page} with refcount {refs}",
                    lane.lane, lane.id, lane.pos),
            });
        }
    }
}

/// Run every per-shard predicate over one scheduler snapshot.
pub fn check_sched(view: &impl SchedView) -> Vec<Violation> {
    let mut out = Vec::new();
    page_conservation(view, &mut out);
    refcount_consistency(view, &mut out);
    table_sanity(view, &mut out);
    cow_write_safety(view, &mut out);
    out
}

/// Assert-style wrapper for test suites and the engine's debug probe:
/// panics with every violation when the snapshot is inconsistent.
///
/// # Panics
///
/// Panics listing every violated invariant, prefixed by `ctx`.
pub fn assert_clean(view: &impl SchedView, ctx: &str) {
    let violations = check_sched(view);
    assert!(
        violations.is_empty(),
        "{ctx}: {} KV invariant violation(s):\n  {}",
        violations.len(),
        violations.iter().map(|v| v.to_string())
            .collect::<Vec<_>>().join("\n  "),
    );
}

// ---------------------------------------------------------------------------
// Fleet-level predicates (across shards / across the episode)
// ---------------------------------------------------------------------------

/// `request-aliasing`: a request id may be in flight or queued on at
/// most ONE shard at a time — a migration that forgot to extract, or a
/// placement that double-submitted, shows up as the same id alive in
/// two schedulers.
pub fn request_aliasing<'a, V: SchedView + 'a>(
    views: impl IntoIterator<Item = &'a V>,
    out: &mut Vec<Violation>,
) {
    let mut owner: HashMap<u64, usize> = HashMap::new();
    for (shard, view) in views.into_iter().enumerate() {
        for id in view.inflight_ids().into_iter().chain(view.queued_ids()) {
            if let Some(prev) = owner.insert(id, shard) {
                out.push(Violation {
                    invariant: "request-aliasing",
                    detail: format!(
                        "request {id} is live on shard {prev} AND shard \
                         {shard}"),
                });
            }
        }
    }
}

/// Episode-long stream accounting for `completion-exactly-once` and
/// `migration-balance`: the driving harness (fuzz loop, model checker)
/// records what it submitted, what completed and how many lanes it
/// moved, then asks for the verdict at drain.
#[derive(Debug, Clone, Default)]
pub struct StreamLog {
    /// Ids handed to `submit`, in order.
    pub submitted: Vec<u64>,
    /// Ids that completed, in completion order (duplicates preserved).
    pub completed: Vec<u64>,
    /// Lanes extracted from donor shards (`take_migratable`).
    pub migrations_taken: usize,
    /// Lanes rebuilt on destination shards (`import_migrated`).
    pub migrations_imported: usize,
}

impl StreamLog {
    /// `completion-exactly-once` mid-episode: no id may complete twice
    /// and no unknown id may complete, even before the drain.
    pub fn check_partial(&self, out: &mut Vec<Violation>) {
        let mut seen = std::collections::HashSet::new();
        for &id in &self.completed {
            if !seen.insert(id) {
                out.push(Violation {
                    invariant: "completion-exactly-once",
                    detail: format!("request {id} completed twice"),
                });
            }
            if !self.submitted.contains(&id) {
                out.push(Violation {
                    invariant: "completion-exactly-once",
                    detail: format!("unknown request {id} completed"),
                });
            }
        }
    }

    /// Drain-time verdict: completions are a permutation of
    /// submissions, and every migrated lane was imported exactly once.
    pub fn check_drained(&self, out: &mut Vec<Violation>) {
        self.check_partial(out);
        let mut got = self.completed.clone();
        got.sort_unstable();
        let mut want = self.submitted.clone();
        want.sort_unstable();
        if got != want {
            out.push(Violation {
                invariant: "completion-exactly-once",
                detail: format!(
                    "completions {got:?} are not a permutation of \
                     submissions {want:?}"),
            });
        }
        if self.migrations_taken != self.migrations_imported {
            out.push(Violation {
                invariant: "migration-balance",
                detail: format!(
                    "{} lanes taken from donors, {} imported",
                    self.migrations_taken, self.migrations_imported),
            });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A hand-rolled view for predicate unit tests: the predicates see
    /// exactly what the struct says, so each invariant can be broken
    /// in isolation without corrupting a real pool.
    struct FakeView {
        total: usize,
        free: usize,
        refs: Vec<u32>,
        page_len: usize,
        lanes: Vec<LaneSnapshot>,
        prefix: Vec<u32>,
        queued: Vec<u64>,
    }

    impl FakeView {
        fn clean() -> Self {
            // 4 pages: lane 0 holds [0, 1] writing at row 5 (page 1),
            // page 2 shared (lane + index) with lane 1's cursor past
            // the resident span (next page not yet allocated — lazy),
            // page 3 free
            FakeView {
                total: 4,
                free: 1,
                refs: vec![1, 1, 2, 0],
                page_len: 4,
                lanes: vec![
                    LaneSnapshot { lane: 0, id: 7, table: vec![0, 1], pos: 5 },
                    LaneSnapshot { lane: 1, id: 8, table: vec![2], pos: 4 },
                ],
                prefix: vec![2],
                queued: vec![],
            }
        }
    }

    impl PoolView for FakeView {
        fn total_pages(&self) -> usize {
            self.total
        }

        fn free_pages(&self) -> usize {
            self.free
        }

        fn page_refcount(&self, page: u32) -> u32 {
            self.refs.get(page as usize).copied().unwrap_or(0)
        }
    }

    impl SchedView for FakeView {
        fn page_len(&self) -> usize {
            self.page_len
        }

        fn lane_snapshots(&self) -> Vec<LaneSnapshot> {
            self.lanes.clone()
        }

        fn prefix_retained(&self) -> Vec<u32> {
            self.prefix.clone()
        }

        fn inflight_ids(&self) -> Vec<u64> {
            self.lanes.iter().map(|l| l.id).collect()
        }

        fn queued_ids(&self) -> Vec<u64> {
            self.queued.clone()
        }
    }

    fn ids(violations: &[Violation]) -> Vec<&'static str> {
        violations.iter().map(|v| v.invariant).collect()
    }

    #[test]
    fn clean_view_has_no_violations() {
        assert_eq!(check_sched(&FakeView::clean()), Vec::new());
    }

    #[test]
    fn leaked_page_breaks_conservation_and_refcounts() {
        let mut v = FakeView::clean();
        v.refs[3] = 1; // page 3 claims an owner but nobody references it
        v.free = 0;
        let got = check_sched(&v);
        assert!(ids(&got).contains(&"refcount-consistency"), "{got:?}");
    }

    #[test]
    fn free_list_desync_breaks_conservation() {
        let mut v = FakeView::clean();
        v.free = 2; // free list says 2, refcounts say 1
        let got = check_sched(&v);
        assert!(ids(&got).contains(&"page-conservation"), "{got:?}");
    }

    #[test]
    fn undercounted_shared_page_is_flagged() {
        let mut v = FakeView::clean();
        v.refs[2] = 1; // lane 1 AND the index reference it
        let got = check_sched(&v);
        assert!(ids(&got).contains(&"refcount-consistency"), "{got:?}");
    }

    #[test]
    fn write_cursor_on_shared_page_is_flagged() {
        let mut v = FakeView::clean();
        // pull lane 1's cursor back onto page 2, which has refcount 2
        v.lanes[1].pos = 0;
        let got = check_sched(&v);
        assert!(ids(&got).contains(&"cow-write-safety"), "{got:?}");
    }

    #[test]
    fn duplicate_page_in_one_table_is_flagged() {
        let mut v = FakeView::clean();
        v.lanes[0].table = vec![0, 0];
        v.refs[0] = 2;
        v.refs[1] = 0;
        v.free = 2;
        let got = check_sched(&v);
        assert!(ids(&got).contains(&"table-sanity"), "{got:?}");
    }

    #[test]
    fn cross_shard_request_alias_is_flagged() {
        let a = FakeView::clean();
        let mut b = FakeView::clean();
        b.lanes.truncate(1); // id 7 in flight on both shards
        let mut out = Vec::new();
        request_aliasing([&a, &b], &mut out);
        assert!(ids(&out).contains(&"request-aliasing"), "{out:?}");
    }

    #[test]
    fn stream_log_catches_duplicates_and_imbalance() {
        let log = StreamLog {
            submitted: vec![1, 2],
            completed: vec![1, 1, 3],
            migrations_taken: 2,
            migrations_imported: 1,
        };
        let mut out = Vec::new();
        log.check_drained(&mut out);
        let got = ids(&out);
        assert!(got.contains(&"completion-exactly-once"), "{out:?}");
        assert!(got.contains(&"migration-balance"), "{out:?}");
    }
}
