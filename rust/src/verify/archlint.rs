//! Architectural lint: dependency-free source rules the compiler
//! cannot express (ISSUE 9 tentpole, layer 3).
//!
//! `rustc` enforces privacy, clippy enforces style — neither can say
//! "KV page ownership mutations belong to exactly two files" or "the
//! coordinator façade never panics on user input". These are
//! ARCHITECTURAL decisions, and they erode one innocent-looking commit
//! at a time. This scanner pins them in CI (`flexllm verify
//! --arch-lint`):
//!
//! | rule | what it pins |
//! |------|--------------|
//! | `pool-ownership` | `pool.alloc(` / `pool.release(` / `pool.retain(` appear only in `coordinator/kv.rs` and `coordinator/scheduler.rs` — every page ownership change flows through the two files the invariant predicates audit. |
//! | `page-encapsulation` | the pool's internal arrays (`.refs[`, `.free[`, `.headers[`) are indexed only inside `coordinator/kv.rs`. |
//! | `no-panic-facade` | no `.unwrap()` / `.expect(` in `coordinator/mod.rs` non-test code — the Router façade turns errors into values, never panics (it owns shard threads; a panic poisons the fleet). |
//! | `debug-everywhere` | every `pub struct` / `pub enum` in `coordinator/` derives or implements `Debug`, so counterexamples and violation reports can always print the state they indict. |
//!
//! The scan is linewise and deliberately dumb: no parser, no syn, no
//! dependencies — false positives are handled by an explicit
//! `// archlint: allow` on the offending or preceding line, which is
//! itself greppable (an audit trail of every exemption). Test modules
//! (everything from the first `#[cfg(test)]` line on) are exempt:
//! archlint governs production code.

use std::fs;
use std::path::{Path, PathBuf};

use crate::anyhow::{anyhow, Result};

/// One broken architecture rule.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LintViolation {
    /// Path relative to the scanned source root.
    pub file: String,
    /// 1-based line of the offending declaration or call.
    pub line: usize,
    /// Stable rule id (the table in the module docs).
    pub rule: &'static str,
    pub detail: String,
}

impl std::fmt::Display for LintViolation {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}:{} [{}] {}", self.file, self.line, self.rule,
               self.detail)
    }
}

/// The crate source root this binary was built from — the default
/// scan target for `flexllm verify --arch-lint` and the tier-1 suite.
pub fn default_src_root() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("rust").join("src")
}

/// Scan every `.rs` file under `src_root` and return all rule
/// violations (empty = architecture holds).
pub fn lint(src_root: &Path) -> Result<Vec<LintViolation>> {
    let mut files = Vec::new();
    collect_rs(src_root, &mut files)?;
    files.sort();
    let mut sources = Vec::with_capacity(files.len());
    for path in &files {
        let rel = path.strip_prefix(src_root).unwrap_or(path)
            .to_string_lossy().replace('\\', "/");
        let text = fs::read_to_string(path)
            .map_err(|e| anyhow!("read {}: {e}", path.display()))?;
        sources.push((rel, text));
    }
    // the Debug rule accepts a manual `impl ... Debug for T` anywhere
    // in the crate, so the whole source set is the lookup corpus
    let corpus: String = sources.iter()
        .map(|(_, text)| text.as_str())
        .collect::<Vec<_>>()
        .join("\n");
    let mut out = Vec::new();
    for (rel, text) in &sources {
        lint_source(rel, text, &corpus, &mut out);
    }
    Ok(out)
}

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) -> Result<()> {
    let entries = fs::read_dir(dir)
        .map_err(|e| anyhow!("read dir {}: {e}", dir.display()))?;
    for entry in entries {
        let entry = entry.map_err(|e| anyhow!("walk {}: {e}", dir.display()))?;
        let path = entry.path();
        if path.is_dir() {
            collect_rs(&path, out)?;
        } else if path.extension().is_some_and(|x| x == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

/// Apply every rule to one file. `rel` is the path relative to the
/// source root (forward slashes); `corpus` is the concatenated crate
/// source (for manual `Debug` impl lookup).
pub fn lint_source(rel: &str, text: &str, corpus: &str,
                   out: &mut Vec<LintViolation>)
{
    let fname = rel.rsplit('/').next().unwrap_or(rel);
    let in_coordinator = rel.starts_with("coordinator/");
    // patterns are assembled at runtime so this scanner never matches
    // its own source
    let pool_calls: Vec<String> = ["alloc", "release", "retain"]
        .iter().map(|m| format!("pool.{m}(")).collect();
    let pool_fields: Vec<String> = ["refs", "free", "headers"]
        .iter().map(|f| format!(".{f}[")).collect();
    let unwraps: Vec<String> = [("unwrap", "()"), ("expect", "(")]
        .iter().map(|(m, tail)| format!(".{m}{tail}")).collect();

    let lines: Vec<&str> = text.lines().collect();
    let mut prev: &str = "";
    for (i, &line) in lines.iter().enumerate() {
        let trimmed = line.trim_start();
        if trimmed.starts_with("#[cfg(test)]") {
            break; // test modules are exempt from every rule
        }
        let allowed = line.contains("archlint: allow")
            || prev.contains("archlint: allow");
        prev = line;
        if allowed || trimmed.starts_with("//") {
            continue;
        }
        let lineno = i + 1;
        if fname != "kv.rs" && fname != "scheduler.rs" {
            for pat in &pool_calls {
                if line.contains(pat.as_str()) {
                    out.push(LintViolation {
                        file: rel.to_string(),
                        line: lineno,
                        rule: "pool-ownership",
                        detail: format!(
                            "`{pat}..` outside coordinator/kv.rs and \
                             coordinator/scheduler.rs — page ownership \
                             mutations are confined to the audited files"),
                    });
                }
            }
        }
        if fname != "kv.rs" {
            for pat in &pool_fields {
                if line.contains(pat.as_str()) {
                    out.push(LintViolation {
                        file: rel.to_string(),
                        line: lineno,
                        rule: "page-encapsulation",
                        detail: format!(
                            "`{pat}..` outside coordinator/kv.rs — the \
                             pool's arrays are not indexed directly"),
                    });
                }
            }
        }
        if rel == "coordinator/mod.rs" {
            for pat in &unwraps {
                if line.contains(pat.as_str()) {
                    out.push(LintViolation {
                        file: rel.to_string(),
                        line: lineno,
                        rule: "no-panic-facade",
                        detail: format!(
                            "`{pat}..` in the Router façade — a panic \
                             here poisons every shard thread; return \
                             the error instead"),
                    });
                }
            }
        }
        if in_coordinator {
            if let Some(name) = public_type_name(trimmed) {
                if !has_debug(&lines, i, name, corpus) {
                    out.push(LintViolation {
                        file: rel.to_string(),
                        line: lineno,
                        rule: "debug-everywhere",
                        detail: format!(
                            "public coordinator type `{name}` has no \
                             Debug — violation reports and \
                             counterexamples must be able to print it"),
                    });
                }
            }
        }
    }
}

/// The identifier of a `pub struct` / `pub enum` declaration, if this
/// line is one.
fn public_type_name(trimmed: &str) -> Option<&str> {
    let rest = trimmed.strip_prefix("pub struct ")
        .or_else(|| trimmed.strip_prefix("pub enum "))?;
    let end = rest.find(|c: char| !c.is_alphanumeric() && c != '_')
        .unwrap_or(rest.len());
    let name = &rest[..end];
    (!name.is_empty()).then_some(name)
}

/// Whether the declaration at `decl` (index into `lines`) carries
/// Debug: a `derive(.., Debug, ..)` in the attributes directly above
/// it, or a manual `impl .. Debug for Name` anywhere in the corpus.
fn has_debug(lines: &[&str], decl: usize, name: &str, corpus: &str) -> bool {
    for back in 1..=10 {
        let Some(j) = decl.checked_sub(back) else { break };
        let t = lines[j].trim_start();
        let attr_or_doc = t.starts_with("#[") || t.starts_with("//");
        if t.starts_with("#[derive(") && t.contains("Debug") {
            return true;
        }
        if !attr_or_doc {
            break;
        }
    }
    let needle = format!("Debug for {name}");
    let mut hay = corpus;
    while let Some(at) = hay.find(&needle) {
        let after = &hay[at + needle.len()..];
        let boundary = after.chars().next()
            .map_or(true, |c| !c.is_alphanumeric() && c != '_');
        if boundary {
            return true;
        }
        hay = after;
    }
    false
}

#[cfg(test)]
mod tests {
    use super::*;

    fn lint_one(rel: &str, text: &str, corpus: &str) -> Vec<LintViolation> {
        let mut out = Vec::new();
        lint_source(rel, text, corpus, &mut out);
        out
    }

    #[test]
    fn pool_calls_confined_to_kv_and_scheduler() {
        let src = "fn f(p: &mut KvPool) { p.pool.release(vec![1]); }\n";
        let hits = lint_one("coordinator/engine.rs", src, "");
        assert_eq!(hits.len(), 1);
        assert_eq!(hits[0].rule, "pool-ownership");
        assert_eq!(hits[0].line, 1);
        assert!(lint_one("coordinator/kv.rs", src, "").is_empty());
        assert!(lint_one("coordinator/scheduler.rs", src, "").is_empty());
    }

    #[test]
    fn pool_arrays_only_indexed_in_kv() {
        let src = "fn f(&self) -> u32 { self.refs[0] }\n";
        let hits = lint_one("coordinator/scheduler.rs", src, "");
        assert_eq!(hits.len(), 1);
        assert_eq!(hits[0].rule, "page-encapsulation");
        assert!(lint_one("coordinator/kv.rs", src, "").is_empty());
    }

    #[test]
    fn facade_rule_hits_mod_rs_only_outside_tests() {
        let src = "fn f() { x.unwrap(); }\n\
                   #[cfg(test)]\n\
                   mod tests { fn g() { y.unwrap(); } }\n";
        let hits = lint_one("coordinator/mod.rs", src, "");
        assert_eq!(hits.len(), 1, "test region is exempt: {hits:?}");
        assert_eq!(hits[0].rule, "no-panic-facade");
        assert!(lint_one("coordinator/engine.rs", src, "").is_empty(),
                "the facade rule is scoped to mod.rs");
    }

    #[test]
    fn allow_marker_exempts_a_line() {
        let src = "// archlint: allow (recovery path, can't fail)\n\
                   fn f() { x.unwrap(); }\n\
                   fn g() { y.expect(\"boom\"); } // archlint: allow\n";
        assert!(lint_one("coordinator/mod.rs", src, "").is_empty());
    }

    #[test]
    fn public_coordinator_types_need_debug() {
        let bare = "pub struct Widget {\n    x: u32,\n}\n";
        let hits = lint_one("coordinator/kv.rs", bare, "");
        assert_eq!(hits.len(), 1);
        assert_eq!(hits[0].rule, "debug-everywhere");

        let derived = "#[derive(Debug, Clone)]\npub struct Widget;\n";
        assert!(lint_one("coordinator/kv.rs", derived, "").is_empty());

        let manual = "impl<B: Backend> fmt::Debug for Widget<B> {}\n";
        assert!(lint_one("coordinator/kv.rs", bare, manual).is_empty(),
                "a manual impl anywhere in the crate satisfies the rule");
        assert_eq!(lint_one("coordinator/kv.rs", bare,
                            "impl fmt::Debug for WidgetFoo {}").len(), 1,
                   "identifier must match on a word boundary");
    }

    #[test]
    fn non_coordinator_files_skip_debug_rule() {
        let bare = "pub struct Widget;\n";
        assert!(lint_one("eval/figures.rs", bare, "").is_empty());
    }

    /// The real tree holds every rule (the same claim CI gates).
    #[test]
    fn crate_source_is_clean() {
        let root = default_src_root();
        let hits = lint(&root).expect("source root readable");
        assert!(hits.is_empty(), "architecture violations:\n{}",
                hits.iter().map(ToString::to_string)
                    .collect::<Vec<_>>().join("\n"));
    }
}
