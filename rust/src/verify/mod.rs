//! The verify subsystem (ISSUE 9): one place where the serving spine's
//! correctness story lives, in three layers that share ONE set of
//! predicates.
//!
//! * [`invariants`] — pure predicates over snapshot views of the
//!   [`KvPool`](crate::coordinator::KvPool) and
//!   [`Scheduler`](crate::coordinator::Scheduler): page conservation,
//!   refcount consistency, table sanity, COW write safety, cross-shard
//!   aliasing, exactly-once completion/migration accounting. The SAME
//!   functions run as the debug-build per-tick probe inside
//!   `Engine::step`, inside the tier-1 fuzz suites, and under the
//!   model checker — a predicate can never drift between its users.
//! * [`mc`] — a bounded exhaustive model checker that drives the REAL
//!   scheduler/pool through every interleaving of a small decision
//!   space (arrival order, tick order, migration timing) across the
//!   {reservation} × {sharing} × {topology} × {codec} matrix, asserting
//!   the layer-1 predicates after every action and minimizing any
//!   violation into a replayable counterexample.
//! * [`archlint`] — a dependency-free source scanner for the
//!   architecture rules the compiler cannot see (page-ownership
//!   confinement, façade panic-freedom, Debug everywhere), gated in CI
//!   next to the checker.
//!
//! [`mutants`] closes the loop: known-fatal faults behind the
//! `verify-mutants` feature, so the tier-1 gate can prove the checker
//! CATCHES the bug classes it exists for — a checker that has never
//! seen red is untested equipment.

pub mod archlint;
pub mod invariants;
pub mod mc;
pub mod mutants;
