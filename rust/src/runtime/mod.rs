//! PJRT runtime: load and execute the AOT-compiled JAX/Pallas artifacts.
//!
//! Python lowers every L2 graph to **HLO text** at build time
//! (`make artifacts`); this module loads the text through
//! `HloModuleProto::from_text_file`, compiles it on the PJRT CPU client,
//! and executes it from the serving hot path. Python is never on the
//! request path.
//!
//! NOTE: the `xla` crate's client is `Rc`-based (not `Send`), so a
//! [`Runtime`] must be owned by a single thread; the coordinator runs it
//! on a dedicated engine thread behind channels.

mod manifest;

/// Stand-in for the `xla` bindings when the `pjrt` feature is off (the
/// default, dependency-free build): same API, errors at first use. With
/// `--features pjrt` the extern crate resolves instead and this module
/// is not compiled.
#[cfg(not(feature = "pjrt"))]
#[path = "xla_stub.rs"]
pub(crate) mod xla;

pub use manifest::{ArtifactEntry, Manifest, SchemeStats, TensorSpec};

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;

use crate::anyhow::{anyhow, Context, Result};

/// A loaded-and-compiled artifact cache over the PJRT CPU client.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    pub manifest: Manifest,
    cache: RefCell<HashMap<String, Rc<xla::PjRtLoadedExecutable>>>,
}

// Manual: the PJRT client and executable cache are runtime handles
// without Debug under the real bindings.
impl std::fmt::Debug for Runtime {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Runtime")
            .field("dir", &self.dir)
            .field("manifest", &self.manifest)
            .finish_non_exhaustive()
    }
}

impl Runtime {
    /// Open the artifact directory (must contain `manifest.json`).
    pub fn open(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest_path = dir.join("manifest.json");
        let manifest = Manifest::parse(
            &std::fs::read_to_string(&manifest_path)
                .with_context(|| format!("reading {manifest_path:?} — run `make artifacts`"))?,
        )
        .context("parsing manifest.json")?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT CPU client: {e}"))?;
        Ok(Runtime { client, dir, manifest, cache: RefCell::new(HashMap::new()) })
    }

    /// Default artifact location relative to the repo root.
    pub fn open_default() -> Result<Self> {
        Self::open("artifacts")
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Artifact names available in the manifest.
    pub fn artifact_names(&self) -> Vec<String> {
        let mut v: Vec<String> = self.manifest.artifacts.keys().cloned().collect();
        v.sort();
        v
    }

    /// Path of the artifact directory.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Load + compile an artifact (cached after the first call).
    pub fn load(&self, name: &str) -> Result<Rc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.borrow().get(name) {
            return Ok(exe.clone());
        }
        let entry = self
            .manifest
            .artifacts
            .get(name)
            .ok_or_else(|| anyhow!("unknown artifact {name:?}"))?;
        let path = self.dir.join(&entry.path);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow!("parsing HLO text {path:?}: {e}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = Rc::new(
            self.client
                .compile(&comp)
                .map_err(|e| anyhow!("compiling {name}: {e}"))?,
        );
        self.cache.borrow_mut().insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Execute an artifact on literal inputs; returns the flattened tuple
    /// elements (aot.py lowers with `return_tuple=True`).
    pub fn execute(&self, name: &str, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let entry = self
            .manifest
            .artifacts
            .get(name)
            .ok_or_else(|| anyhow!("unknown artifact {name:?}"))?;
        if entry.inputs.len() != inputs.len() {
            return Err(anyhow!(
                "{name}: expected {} inputs, got {}",
                entry.inputs.len(),
                inputs.len()
            ));
        }
        for (spec, lit) in entry.inputs.iter().zip(inputs) {
            let n: usize = spec.shape.iter().product::<u64>() as usize;
            if lit.element_count() != n {
                return Err(anyhow!(
                    "{name}: input {} expects {} elements ({:?}), got {}",
                    spec.name, n, spec.shape, lit.element_count()
                ));
            }
        }
        let exe = self.load(name)?;
        let result = exe
            .execute::<xla::Literal>(inputs)
            .map_err(|e| anyhow!("executing {name}: {e}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching {name} result: {e}"))?;
        result.to_tuple().map_err(|e| anyhow!("untupling {name}: {e}"))
    }
}

// ---------------------------------------------------------------------------
// Literal helpers
// ---------------------------------------------------------------------------

/// Build an f32 literal of the given shape.
pub fn lit_f32(data: &[f32], shape: &[i64]) -> Result<xla::Literal> {
    let n: i64 = shape.iter().product();
    if n as usize != data.len() {
        return Err(anyhow!("shape {shape:?} wants {n} elems, got {}", data.len()));
    }
    xla::Literal::vec1(data)
        .reshape(shape)
        .map_err(|e| anyhow!("reshape: {e}"))
}

/// Build an i32 literal of the given shape.
pub fn lit_i32(data: &[i32], shape: &[i64]) -> Result<xla::Literal> {
    let n: i64 = shape.iter().product();
    if n as usize != data.len() {
        return Err(anyhow!("shape {shape:?} wants {n} elems, got {}", data.len()));
    }
    xla::Literal::vec1(data)
        .reshape(shape)
        .map_err(|e| anyhow!("reshape: {e}"))
}

/// Build an i8 literal of the given shape (quantized KV page pools).
pub fn lit_i8(data: &[i8], shape: &[i64]) -> Result<xla::Literal> {
    let n: i64 = shape.iter().product();
    if n as usize != data.len() {
        return Err(anyhow!("shape {shape:?} wants {n} elems, got {}", data.len()));
    }
    xla::Literal::vec1(data)
        .reshape(shape)
        .map_err(|e| anyhow!("reshape: {e}"))
}

/// Scalar i32 literal (e.g. the decode position).
pub fn lit_scalar_i32(v: i32) -> xla::Literal {
    xla::Literal::scalar(v)
}

/// Extract f32 data from a literal.
pub fn to_f32(lit: &xla::Literal) -> Result<Vec<f32>> {
    lit.to_vec::<f32>().map_err(|e| anyhow!("to_vec<f32>: {e}"))
}

/// Row-wise argmax over a [rows, cols] f32 literal.
pub fn argmax_rows(lit: &xla::Literal, rows: usize, cols: usize) -> Result<Vec<i32>> {
    let data = to_f32(lit)?;
    if data.len() != rows * cols {
        return Err(anyhow!("argmax: want {}x{}={} elems, got {}", rows, cols,
                         rows * cols, data.len()));
    }
    Ok((0..rows)
        .map(|r| {
            let row = &data[r * cols..(r + 1) * cols];
            row.iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap_or(std::cmp::Ordering::Equal))
                .map(|(i, _)| i as i32)
                .unwrap_or(0)
        })
        .collect())
}

/// Summed negative log-likelihood of next-token targets from
/// full-sequence logits [b, s, v] — the perplexity harness core.
/// Returns (total NLL, prediction count).
pub fn nll_from_logits(logits: &[f32], tokens: &[i32], b: usize, s: usize, v: usize)
    -> (f64, usize)
{
    assert_eq!(logits.len(), b * s * v, "logits size");
    assert_eq!(tokens.len(), b * s, "tokens size");
    let mut total = 0.0f64;
    let mut count = 0usize;
    for bi in 0..b {
        for si in 0..s - 1 {
            let row = &logits[(bi * s + si) * v..(bi * s + si + 1) * v];
            let target = tokens[bi * s + si + 1] as usize;
            let m = row.iter().fold(f32::NEG_INFINITY, |a, &x| a.max(x));
            let lse: f64 = row.iter().map(|&x| ((x - m) as f64).exp()).sum::<f64>().ln()
                + m as f64;
            total += lse - row[target] as f64;
            count += 1;
        }
    }
    (total, count)
}

#[cfg(test)]
mod tests {
    use super::*;

    // literal round-trips touch the real bindings; the stub build
    // (default features) exercises only the pure-Rust helpers
    #[cfg(feature = "pjrt")]
    #[test]
    fn lit_roundtrip() {
        let l = lit_f32(&[1.0, 2.0, 3.0, 4.0], &[2, 2]).unwrap();
        assert_eq!(to_f32(&l).unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(l.element_count(), 4);
    }

    #[test]
    fn lit_shape_mismatch_rejected() {
        assert!(lit_f32(&[1.0, 2.0, 3.0], &[2, 2]).is_err());
        assert!(lit_i32(&[1, 2, 3, 4, 5], &[2, 2]).is_err());
    }

    #[cfg(feature = "pjrt")]
    #[test]
    fn argmax_rows_works() {
        let l = lit_f32(&[0.1, 0.9, 0.5, 2.0, -1.0, 0.0], &[2, 3]).unwrap();
        assert_eq!(argmax_rows(&l, 2, 3).unwrap(), vec![1, 0]);
    }

    #[test]
    fn nll_uniform_logits_is_log_v() {
        // uniform logits → NLL = ln(v) per position
        let (b, s, v) = (1, 3, 8);
        let logits = vec![0.0f32; b * s * v];
        let tokens = vec![1i32, 2, 3];
        let (total, count) = nll_from_logits(&logits, &tokens, b, s, v);
        assert_eq!(count, 2);
        assert!((total / count as f64 - (v as f64).ln()).abs() < 1e-9);
    }
}
