//! Compile-time stand-in for the `xla` PJRT bindings.
//!
//! The real bindings wrap a multi-GB `xla_extension` archive and are not
//! on crates.io; the default (offline, dependency-free) build therefore
//! compiles the runtime against this stub, which has the exact API
//! surface `runtime`/`PjrtBackend` use and fails at the first runtime
//! call with a clear message. Building with `--features pjrt` swaps the
//! real crate in (see rust/README.md, "Cargo manifest & vendored
//! registry") without touching any call site: everything refers to the
//! `xla::` paths, which resolve to this module or the extern crate
//! depending on the feature.
//!
//! Artifact-free code paths (mock/modeled backends, the simulator, every
//! tier-1 test) never construct a PJRT client, so they run identically
//! under the stub.

use std::fmt;

/// Error type mirroring the bindings' (call sites only format it).
#[derive(Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

fn unavailable() -> Error {
    Error(
        "PJRT support is not compiled in: build with `--features pjrt` and the \
         vendored `xla` crate (rust/README.md) to execute artifacts"
            .into(),
    )
}

/// Host-side tensor value.
#[derive(Debug, Clone)]
pub struct Literal;

impl Literal {
    pub fn vec1<T>(_data: &[T]) -> Literal {
        Literal
    }

    pub fn scalar<T>(_v: T) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal, Error> {
        Err(unavailable())
    }

    pub fn element_count(&self) -> usize {
        0
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>, Error> {
        Err(unavailable())
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>, Error> {
        Err(unavailable())
    }
}

#[derive(Debug)]
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, Error> {
        Err(unavailable())
    }

    pub fn platform_name(&self) -> String {
        "stub".into()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        Err(unavailable())
    }
}

#[derive(Debug)]
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        Err(unavailable())
    }
}

#[derive(Debug)]
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        Err(unavailable())
    }
}

#[derive(Debug)]
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto, Error> {
        Err(unavailable())
    }
}

#[derive(Debug)]
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}
