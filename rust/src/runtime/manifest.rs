//! `artifacts/manifest.json` schema (written by python/compile/aot.py),
//! parsed with the in-tree JSON parser (offline build — no serde).

use std::collections::HashMap;

use crate::anyhow::{anyhow, Context, Result};

use crate::util::Json;

/// Tensor shape/dtype descriptor for artifact I/O.
#[derive(Debug, Clone)]
pub struct TensorSpec {
    pub name: String,
    pub dtype: String,
    pub shape: Vec<u64>,
}

/// One AOT-compiled HLO artifact.
#[derive(Debug, Clone)]
pub struct ArtifactEntry {
    pub path: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

/// Build-time stats for one quantization scheme (Table V row).
#[derive(Debug, Clone)]
pub struct SchemeStats {
    /// Build-time (Python) perplexity — Rust cross-checks within 2%.
    pub ppl: f64,
    pub w_bits: Option<u64>,
    pub a_bits: Option<u64>,
    pub attn_mode: String,
    pub kv_bits: Option<u64>,
    pub lm_head_quant: bool,
}

/// Tiny-model configuration baked into the artifacts.
#[derive(Debug, Clone)]
pub struct ModelInfo {
    pub n_layers: u64,
    pub d_model: u64,
    pub n_heads: u64,
    pub n_kv_heads: u64,
    pub d_ffn: u64,
    pub vocab: u64,
    pub max_seq: u64,
}

/// Serving shapes fixed at AOT time.
#[derive(Debug, Clone)]
pub struct ServingInfo {
    pub batch: usize,
    pub prefill_len: usize,
    /// Chunk width of the `prefill_chunk_q3` artifact; absent in
    /// artifact sets that predate chunked admission.
    pub prefill_chunk: Option<usize>,
    pub cache_shape: Vec<u64>,
    /// Paged-pool geometry (`decode_paged_q3` + `prefill_chunk_paged_q3`
    /// artifacts); all absent in pre-paging artifact sets. `kv_pages`
    /// counts ALLOCATABLE pages — the physical pool holds one more
    /// (page 0, the idle-lane scratch page).
    pub page_len: Option<usize>,
    pub kv_pages: Option<usize>,
    pub pages_per_lane: Option<usize>,
    pub page_cache_shape: Option<Vec<u64>>,
    /// Page-pool storage codec of the quantized artifacts
    /// (`decode_paged_q3_kv8` + `prefill_chunk_paged_q3_kv8`):
    /// `"int8_sym"` when the pool literals are true INT8 with per-page
    /// scale headers. Absent in fp artifact sets.
    pub kv_codec: Option<String>,
    /// Scale-header shape `[L, pages + scratch]` per K and V (f32),
    /// present iff `kv_codec` is.
    pub kv_header_shape: Option<Vec<u64>>,
}

/// Held-out eval batch layout (`eval_tokens.bin`).
#[derive(Debug, Clone)]
pub struct EvalInfo {
    pub n_batches: usize,
    pub batch: usize,
    pub seq: usize,
}

/// HMT artifact shapes.
#[derive(Debug, Clone)]
pub struct HmtInfo {
    pub batch: usize,
    pub n_memories: usize,
}

/// Deterministic kernel-smoke vector for runtime unit tests.
#[derive(Debug, Clone)]
pub struct SmokeInfo {
    pub x: Vec<f32>,
    pub w: Vec<f32>,
    pub y: Vec<f32>,
}

/// The whole manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub model: ModelInfo,
    pub artifacts: HashMap<String, ArtifactEntry>,
    pub schemes: HashMap<String, SchemeStats>,
    pub serving: ServingInfo,
    pub eval: EvalInfo,
    pub hmt: HmtInfo,
    pub smoke: SmokeInfo,
    pub fp_ppl: f64,
    /// Greedy generation reference [batch][steps] from build time.
    pub greedy_reference: Vec<Vec<i32>>,
}

// ---- JSON → struct helpers -------------------------------------------

fn req<'a>(j: &'a Json, key: &str) -> Result<&'a Json> {
    j.get(key).ok_or_else(|| anyhow!("manifest missing key '{key}'"))
}

fn u64_of(j: &Json, key: &str) -> Result<u64> {
    req(j, key)?.as_u64().ok_or_else(|| anyhow!("'{key}' is not a u64"))
}

fn usize_of(j: &Json, key: &str) -> Result<usize> {
    Ok(u64_of(j, key)? as usize)
}

fn f64_of(j: &Json, key: &str) -> Result<f64> {
    req(j, key)?.as_f64().ok_or_else(|| anyhow!("'{key}' is not a number"))
}

fn str_of(j: &Json, key: &str) -> Result<String> {
    Ok(req(j, key)?.as_str().ok_or_else(|| anyhow!("'{key}' is not a string"))?.into())
}

fn u64_vec(j: &Json, key: &str) -> Result<Vec<u64>> {
    req(j, key)?
        .as_arr()
        .ok_or_else(|| anyhow!("'{key}' is not an array"))?
        .iter()
        .map(|v| v.as_u64().ok_or_else(|| anyhow!("'{key}' element not u64")))
        .collect()
}

fn f32_vec(j: &Json, key: &str) -> Result<Vec<f32>> {
    req(j, key)?
        .as_arr()
        .ok_or_else(|| anyhow!("'{key}' is not an array"))?
        .iter()
        .map(|v| v.as_f64().map(|f| f as f32).ok_or_else(|| anyhow!("'{key}' element not f32")))
        .collect()
}

fn tensor_spec(j: &Json) -> Result<TensorSpec> {
    Ok(TensorSpec {
        name: str_of(j, "name")?,
        dtype: str_of(j, "dtype")?,
        shape: u64_vec(j, "shape")?,
    })
}

impl Manifest {
    /// Parse the manifest document.
    pub fn parse(src: &str) -> Result<Manifest> {
        let j = Json::parse(src).map_err(|e| anyhow!("{e}"))?;

        let m = req(&j, "model")?;
        let model = ModelInfo {
            n_layers: u64_of(m, "n_layers")?,
            d_model: u64_of(m, "d_model")?,
            n_heads: u64_of(m, "n_heads")?,
            n_kv_heads: u64_of(m, "n_kv_heads")?,
            d_ffn: u64_of(m, "d_ffn")?,
            vocab: u64_of(m, "vocab")?,
            max_seq: u64_of(m, "max_seq")?,
        };

        let mut artifacts = HashMap::new();
        for (name, entry) in req(&j, "artifacts")?
            .as_obj()
            .ok_or_else(|| anyhow!("'artifacts' not an object"))?
        {
            let inputs = req(entry, "inputs")?
                .as_arr()
                .ok_or_else(|| anyhow!("inputs not array"))?
                .iter()
                .map(tensor_spec)
                .collect::<Result<Vec<_>>>()
                .with_context(|| format!("artifact {name}"))?;
            let outputs = req(entry, "outputs")?
                .as_arr()
                .ok_or_else(|| anyhow!("outputs not array"))?
                .iter()
                .map(tensor_spec)
                .collect::<Result<Vec<_>>>()?;
            artifacts.insert(
                name.clone(),
                ArtifactEntry { path: str_of(entry, "path")?, inputs, outputs },
            );
        }

        let mut schemes = HashMap::new();
        for (name, s) in req(&j, "schemes")?
            .as_obj()
            .ok_or_else(|| anyhow!("'schemes' not an object"))?
        {
            schemes.insert(
                name.clone(),
                SchemeStats {
                    ppl: f64_of(s, "ppl")?,
                    w_bits: s.get("w_bits").and_then(|v| v.as_u64()),
                    a_bits: s.get("a_bits").and_then(|v| v.as_u64()),
                    attn_mode: str_of(s, "attn_mode")?,
                    kv_bits: s.get("kv_bits").and_then(|v| v.as_u64()),
                    lm_head_quant: req(s, "lm_head_quant")?
                        .as_bool()
                        .ok_or_else(|| anyhow!("lm_head_quant not bool"))?,
                },
            );
        }

        let sv = req(&j, "serving")?;
        let opt_usize = |key: &str| {
            sv.get(key).and_then(|v| v.as_u64()).map(|v| v as usize)
        };
        let serving = ServingInfo {
            batch: usize_of(sv, "batch")?,
            prefill_len: usize_of(sv, "prefill_len")?,
            prefill_chunk: opt_usize("prefill_chunk"),
            cache_shape: u64_vec(sv, "cache_shape")?,
            page_len: opt_usize("page_len"),
            kv_pages: opt_usize("kv_pages"),
            pages_per_lane: opt_usize("pages_per_lane"),
            page_cache_shape: if sv.get("page_cache_shape").is_some() {
                Some(u64_vec(sv, "page_cache_shape")?)
            } else {
                None
            },
            kv_codec: sv.get("kv_codec").and_then(|v| v.as_str()).map(String::from),
            kv_header_shape: if sv.get("kv_header_shape").is_some() {
                Some(u64_vec(sv, "kv_header_shape")?)
            } else {
                None
            },
        };

        let ev = req(&j, "eval")?;
        let eval = EvalInfo {
            n_batches: usize_of(ev, "n_batches")?,
            batch: usize_of(ev, "batch")?,
            seq: usize_of(ev, "seq")?,
        };

        let h = req(&j, "hmt")?;
        let hmt = HmtInfo {
            batch: usize_of(h, "batch")?,
            n_memories: usize_of(h, "n_memories")?,
        };

        let sm = req(&j, "smoke")?;
        let smoke = SmokeInfo {
            x: f32_vec(sm, "x")?,
            w: f32_vec(sm, "w")?,
            y: f32_vec(sm, "y")?,
        };

        let greedy_reference = req(&j, "greedy_reference")?
            .as_arr()
            .ok_or_else(|| anyhow!("greedy_reference not array"))?
            .iter()
            .map(|row| {
                row.as_arr()
                    .ok_or_else(|| anyhow!("greedy row not array"))?
                    .iter()
                    .map(|v| v.as_i64().map(|x| x as i32)
                        .ok_or_else(|| anyhow!("greedy token not int")))
                    .collect::<Result<Vec<i32>>>()
            })
            .collect::<Result<Vec<_>>>()?;

        Ok(Manifest {
            model,
            artifacts,
            schemes,
            serving,
            eval,
            hmt,
            smoke,
            fp_ppl: f64_of(&j, "fp_ppl")?,
            greedy_reference,
        })
    }

    /// Ablation scheme names ordered as Table V.
    pub fn scheme_order() -> [&'static str; 5] {
        ["noquant", "q0", "q1", "q2", "q3"]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const MINI: &str = r#"{
      "model": {"n_layers": 2, "d_model": 8, "n_heads": 2, "n_kv_heads": 1,
                "d_ffn": 16, "vocab": 32, "max_seq": 24},
      "artifacts": {"a": {"path": "a.hlo.txt",
                          "inputs": [{"name": "x", "dtype": "f32", "shape": [2, 3]}],
                          "outputs": [{"name": "y", "dtype": "f32", "shape": [2]}]}},
      "schemes": {"q3": {"ppl": 7.9, "w_bits": 4, "a_bits": 4,
                         "attn_mode": "sta8", "kv_bits": 8, "lm_head_quant": true}},
      "serving": {"batch": 4, "prefill_len": 16, "cache_shape": [2, 4, 1, 24, 4]},
      "eval": {"n_batches": 2, "batch": 4, "seq": 8},
      "hmt": {"batch": 1, "n_memories": 8},
      "smoke": {"x": [1.0], "w": [2.0], "y": [2.0]},
      "fp_ppl": 7.6,
      "greedy_reference": [[1, 2], [3, 4]]
    }"#;

    #[test]
    fn parses_mini_manifest() {
        let m = Manifest::parse(MINI).unwrap();
        assert_eq!(m.model.d_model, 8);
        assert_eq!(m.artifacts["a"].inputs[0].shape, vec![2, 3]);
        assert_eq!(m.schemes["q3"].kv_bits, Some(8));
        assert!(m.schemes["q3"].lm_head_quant);
        assert_eq!(m.serving.cache_shape.len(), 5);
        // pre-chunked-prefill artifact sets have no chunk width
        assert_eq!(m.serving.prefill_chunk, None);
        // pre-paging artifact sets have no page geometry
        assert_eq!(m.serving.page_len, None);
        assert_eq!(m.serving.kv_pages, None);
        assert_eq!(m.serving.page_cache_shape, None);
        assert_eq!(m.greedy_reference[1], vec![3, 4]);
    }

    #[test]
    fn parses_prefill_chunk_when_present() {
        let src = MINI.replace("\"prefill_len\": 16,",
                               "\"prefill_len\": 16, \"prefill_chunk\": 4,");
        let m = Manifest::parse(&src).unwrap();
        assert_eq!(m.serving.prefill_chunk, Some(4));
    }

    #[test]
    fn parses_paged_geometry_when_present() {
        let src = MINI.replace(
            "\"prefill_len\": 16,",
            "\"prefill_len\": 16, \"page_len\": 6, \"kv_pages\": 9, \
             \"pages_per_lane\": 4, \"page_cache_shape\": [2, 10, 1, 6, 4],");
        let m = Manifest::parse(&src).unwrap();
        assert_eq!(m.serving.page_len, Some(6));
        assert_eq!(m.serving.kv_pages, Some(9));
        assert_eq!(m.serving.pages_per_lane, Some(4));
        assert_eq!(m.serving.page_cache_shape, Some(vec![2, 10, 1, 6, 4]));
        // fp artifact set: no page codec declared
        assert_eq!(m.serving.kv_codec, None);
        assert_eq!(m.serving.kv_header_shape, None);
    }

    #[test]
    fn parses_kv_codec_when_present() {
        let src = MINI.replace(
            "\"prefill_len\": 16,",
            "\"prefill_len\": 16, \"page_len\": 6, \"kv_pages\": 9, \
             \"pages_per_lane\": 4, \"page_cache_shape\": [2, 10, 1, 6, 4], \
             \"kv_codec\": \"int8_sym\", \"kv_header_shape\": [2, 10],");
        let m = Manifest::parse(&src).unwrap();
        assert_eq!(m.serving.kv_codec.as_deref(), Some("int8_sym"));
        assert_eq!(m.serving.kv_header_shape, Some(vec![2, 10]));
    }

    #[test]
    fn missing_key_is_error() {
        assert!(Manifest::parse("{}").is_err());
    }
}
