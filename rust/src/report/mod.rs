//! Plain-text table / series rendering for the evaluation harness.

/// Render an ASCII table with a header row.
pub fn table(title: &str, headers: &[&str], rows: &[Vec<String>]) -> String {
    let ncols = headers.len();
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for row in rows {
        assert_eq!(row.len(), ncols, "row arity mismatch in {title}");
        for (i, cell) in row.iter().enumerate() {
            widths[i] = widths[i].max(cell.len());
        }
    }
    let sep: String = widths
        .iter()
        .map(|w| "-".repeat(w + 2))
        .collect::<Vec<_>>()
        .join("+");
    let fmt_row = |cells: &[String]| -> String {
        cells
            .iter()
            .enumerate()
            .map(|(i, c)| format!(" {:<w$} ", c, w = widths[i]))
            .collect::<Vec<_>>()
            .join("|")
    };
    let mut out = format!("== {title} ==\n");
    out.push_str(&fmt_row(&headers.iter().map(|s| s.to_string()).collect::<Vec<_>>()));
    out.push('\n');
    out.push_str(&sep);
    out.push('\n');
    for row in rows {
        out.push_str(&fmt_row(row));
        out.push('\n');
    }
    out
}

/// Render a named series as CSV (one figure panel).
pub fn csv(headers: &[&str], rows: &[Vec<String>]) -> String {
    let mut out = headers.join(",");
    out.push('\n');
    for row in rows {
        out.push_str(&row.join(","));
        out.push('\n');
    }
    out
}

/// Format seconds adaptively (s / ms / µs).
pub fn fmt_secs(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.2} s")
    } else if s >= 1e-3 {
        format!("{:.2} ms", s * 1e3)
    } else {
        format!("{:.1} µs", s * 1e6)
    }
}

/// Format a ratio as "N.NN×".
pub fn fmt_ratio(r: f64) -> String {
    format!("{r:.2}×")
}

/// Format a fraction as a percentage.
pub fn fmt_pct(f: f64) -> String {
    format!("{:.1}%", f * 100.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_aligned() {
        let t = table("T", &["a", "long_header"], &[
            vec!["1".into(), "2".into()],
            vec!["333".into(), "4".into()],
        ]);
        assert!(t.contains("== T =="));
        assert!(t.contains("long_header"));
        assert!(t.lines().count() == 5);
    }

    #[test]
    fn fmt_helpers() {
        assert_eq!(fmt_secs(2.5), "2.50 s");
        assert_eq!(fmt_secs(0.0025), "2.50 ms");
        assert_eq!(fmt_ratio(1.289), "1.29×");
        assert_eq!(fmt_pct(0.666), "66.6%");
    }
}
