//! Model dimension sets: the paper's Llama-3.2 1B target and the tiny
//! CPU-executable model baked into the AOT artifacts.


/// Transformer dimensions (paper Table VI row 1 notation).
#[derive(Debug, Clone)]
pub struct ModelDims {
    pub name: String,
    /// N — number of decoder layers.
    pub n_layers: u64,
    /// d_h — hidden dimension.
    pub d_model: u64,
    pub n_heads: u64,
    pub n_kv_heads: u64,
    /// d_kv — total KV projection width (n_kv_heads × head_dim).
    pub d_kv: u64,
    /// d_ffn — FFN intermediate dimension.
    pub d_ffn: u64,
    /// d_lm_head — vocabulary size.
    pub vocab: u64,
    pub max_seq: u64,
}

impl ModelDims {
    /// Llama-3.2 1B: L=16, d=2048, d_kv=512, d_ffn=8192, vocab=128256.
    pub fn llama32_1b() -> Self {
        ModelDims {
            name: "Llama-3.2-1B".into(),
            n_layers: 16,
            d_model: 2048,
            n_heads: 32,
            n_kv_heads: 8,
            d_kv: 512,
            d_ffn: 8192,
            vocab: 128_256,
            max_seq: 131_072,
        }
    }

    /// The tiny artifact model (must match python/compile/model.py::tiny).
    pub fn tiny() -> Self {
        ModelDims {
            name: "tiny-llama-arch".into(),
            n_layers: 4,
            d_model: 256,
            n_heads: 8,
            n_kv_heads: 2,
            d_kv: 64,
            d_ffn: 512,
            vocab: 512,
            max_seq: 320,
        }
    }

    pub fn head_dim(&self) -> u64 {
        self.d_model / self.n_heads
    }

    /// Total parameter count (embedding + per-layer + lm_head).
    pub fn n_params(&self) -> u64 {
        let per_layer = self.d_model * self.d_model          // wq
            + 2 * self.d_model * self.d_kv                   // wk, wv
            + self.d_model * self.d_model                    // wo
            + 3 * self.d_model * self.d_ffn                  // wg, wu, wd
            + 2 * self.d_model;                              // norms
        2 * self.vocab * self.d_model + self.n_layers * per_layer + self.d_model
    }

    /// Weight bytes touched per generated token during decode (all weights
    /// are streamed once per token), given per-site precisions.
    pub fn decode_weight_bytes(&self, linear_bytes: f64, lm_head_bytes: f64) -> f64 {
        let linear = self.n_layers
            * (2 * self.d_model * self.d_kv      // wk, wv
                + 2 * self.d_model * self.d_model // wq, wo
                + 3 * self.d_model * self.d_ffn); // wg, wu, wd
        linear as f64 * linear_bytes + (self.d_model * self.vocab) as f64 * lm_head_bytes
    }

    /// KV-cache bytes per token at context length `ctx` (read K and V for
    /// every layer) with `kv_bytes` per element.
    pub fn kv_bytes_per_token(&self, ctx: u64, kv_bytes: f64) -> f64 {
        (2 * self.n_layers * self.d_kv * ctx) as f64 * kv_bytes
    }

    /// FLOPs for one token of dense forward (2 × params, the standard
    /// decoder estimate the GPU roofline uses).
    pub fn flops_per_token(&self) -> f64 {
        2.0 * self.n_params() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn llama_1b_is_about_1b_params() {
        let m = ModelDims::llama32_1b();
        let p = m.n_params() as f64;
        assert!(p > 1.0e9 && p < 1.6e9, "params = {p}");
        assert_eq!(m.head_dim(), 64);
    }

    #[test]
    fn tiny_matches_python_config() {
        let t = ModelDims::tiny();
        assert_eq!(t.head_dim(), 32);
        assert_eq!(t.d_kv, t.n_kv_heads * t.head_dim());
        // ~2.6M params, small enough for CPU execution
        assert!(t.n_params() < 4_000_000);
    }

    #[test]
    fn decode_weight_traffic_int4() {
        let m = ModelDims::llama32_1b();
        // INT4 linears + INT4 lm_head: roughly half the param count in bytes
        let b = m.decode_weight_bytes(0.5, 0.5);
        assert!(b > 0.4 * m.n_params() as f64 && b < 0.6 * m.n_params() as f64);
    }
}
