//! Numeric precision descriptors used across the module library.
//!
//! The paper's bandwidth equations (Eq. 2/5/7) depend only on
//! bytes-per-element (B_W); the resource models additionally distinguish
//! how a multiply-accumulate of each precision maps onto FPGA fabric
//! (LUT-based INT4 MACs vs DSP-packed INT8 vs full-DSP FP).


/// Element precision of a datapath or stream.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Precision {
    /// 4-bit integer (packed two-per-byte in HBM, LUT MACs on fabric).
    Int4,
    /// 8-bit integer (DSP-packed MACs).
    Int8,
    /// bfloat16 / fp16 — 2 bytes.
    Fp16,
    /// float32 — 4 bytes.
    Fp32,
}

impl Precision {
    /// Bytes per element as seen by the HBM interface (B_W in Eq. 2).
    /// INT4 is 0.5 — the paper packs two nibbles per byte.
    pub fn bytes(self) -> f64 {
        match self {
            Precision::Int4 => 0.5,
            Precision::Int8 => 1.0,
            Precision::Fp16 => 2.0,
            Precision::Fp32 => 4.0,
        }
    }

    /// Bits per element.
    pub fn bits(self) -> u32 {
        match self {
            Precision::Int4 => 4,
            Precision::Int8 => 8,
            Precision::Fp16 => 16,
            Precision::Fp32 => 32,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Precision::Int4 => "INT4",
            Precision::Int8 => "INT8",
            Precision::Fp16 => "FP16",
            Precision::Fp32 => "FP32",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn int4_packs_two_per_byte() {
        assert_eq!(Precision::Int4.bytes(), 0.5);
        assert_eq!(Precision::Int4.bits(), 4);
    }

    #[test]
    fn bytes_match_bits() {
        for p in [Precision::Int4, Precision::Int8, Precision::Fp16, Precision::Fp32] {
            assert!((p.bytes() * 8.0 - p.bits() as f64).abs() < 1e-9);
        }
    }
}
