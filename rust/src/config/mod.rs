//! Device, model and precision configuration (paper Table I + Table VI row 1).

mod device;
mod model;
mod precision;

pub use device::{DeviceConfig, DeviceKind};
pub use model::ModelDims;
pub use precision::Precision;
