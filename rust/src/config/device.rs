//! Hardware platform descriptions (paper Table I).
//!
//! The FPGA resource pools are taken from the public AMD datasheets the
//! paper cites ([32], [33]); the A100 numbers from the NVIDIA datasheet
//! [34]. These caps bound the design-space exploration (`dse`) and the
//! resource accounting of every composed architecture.


use crate::hls::Resources;

/// Which platform a config describes (drives frequency + power models).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DeviceKind {
    U280,
    V80,
    A100,
}

/// One row of Table I plus the FPGA resource pool.
#[derive(Debug, Clone)]
pub struct DeviceConfig {
    pub kind: DeviceKind,
    pub name: &'static str,
    pub tech_node_nm: u32,
    /// Peak compute in FP32 TFLOPS (Table I convention).
    pub peak_tflops: f64,
    /// Peak HBM bandwidth, bytes/second.
    pub hbm_bw: f64,
    /// HBM capacity, bytes.
    pub hbm_capacity: u64,
    /// Peak (board) power, watts.
    pub peak_power_w: f64,
    /// Average measured power under LLM inference load, watts.
    /// (On-board sampling for U280 / synthesis estimate for V80 per the
    /// paper; A100 from nvidia-smi-style sampling under vLLM.)
    pub avg_power_w: f64,
    /// FPGA fabric resource pool; zeroed for GPUs.
    pub resources: Resources,
    /// Nominal target clock before floorplan derating, Hz (FPGA only).
    pub target_clock_hz: f64,
}

impl DeviceConfig {
    /// AMD Alveo U280 (TSMC 16nm) — Table I column 1.
    pub fn u280() -> Self {
        DeviceConfig {
            kind: DeviceKind::U280,
            name: "AMD Alveo U280",
            tech_node_nm: 16,
            peak_tflops: 8.0,
            hbm_bw: 460e9,
            hbm_capacity: 8 << 30,
            peak_power_w: 75.0,
            avg_power_w: 58.0,
            resources: Resources {
                clb: 163_320.0,
                dsp: 9_024.0,
                lut: 1_304_000.0,
                ff: 2_607_000.0,
                bram: 2_016.0,
                uram: 960.0,
            },
            target_clock_hz: 320e6,
        }
    }

    /// AMD Versal V80 (TSMC 7nm) — Table I column 2.
    pub fn v80() -> Self {
        DeviceConfig {
            kind: DeviceKind::V80,
            name: "AMD Versal V80",
            tech_node_nm: 7,
            peak_tflops: 58.0,
            hbm_bw: 820e9,
            hbm_capacity: 32 << 30,
            peak_power_w: 190.0,
            avg_power_w: 140.0,
            resources: Resources {
                clb: 449_000.0,
                dsp: 10_848.0,
                lut: 2_574_000.0,
                ff: 5_148_000.0,
                bram: 3_741.0,
                uram: 1_301.0,
            },
            target_clock_hz: 320e6,
        }
    }

    /// NVIDIA A100 80GB PCIe (TSMC 7nm) — Table I column 3.
    pub fn a100() -> Self {
        DeviceConfig {
            kind: DeviceKind::A100,
            name: "NVIDIA A100 80GB PCIe",
            tech_node_nm: 7,
            peak_tflops: 312.0,
            hbm_bw: 1_935e9,
            hbm_capacity: 80 << 30,
            peak_power_w: 300.0,
            avg_power_w: 240.0,
            resources: Resources::zero(),
            target_clock_hz: 0.0,
        }
    }

    /// Fraction of the resource pool a composed design consumes (0..1 per
    /// class); the max over classes is the binding constraint.
    pub fn utilization(&self, used: &Resources) -> Resources {
        Resources {
            clb: used.clb / self.resources.clb.max(1.0),
            dsp: used.dsp / self.resources.dsp.max(1.0),
            lut: used.lut / self.resources.lut.max(1.0),
            ff: used.ff / self.resources.ff.max(1.0),
            bram: used.bram / self.resources.bram.max(1.0),
            uram: used.uram / self.resources.uram.max(1.0),
        }
    }

    /// True iff `used` fits the pool with the given headroom (e.g. 0.85 →
    /// ≤85% of every class, the practical P&R closure limit).
    pub fn fits(&self, used: &Resources, headroom: f64) -> bool {
        let u = self.utilization(used);
        u.max_class() <= headroom
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_values() {
        let u = DeviceConfig::u280();
        assert_eq!(u.tech_node_nm, 16);
        assert_eq!(u.peak_tflops, 8.0);
        assert_eq!(u.hbm_capacity, 8 << 30);
        let v = DeviceConfig::v80();
        assert_eq!(v.tech_node_nm, 7);
        assert!((v.hbm_bw - 820e9).abs() < 1.0);
        let a = DeviceConfig::a100();
        assert_eq!(a.peak_power_w, 300.0);
        assert_eq!(a.hbm_capacity, 80 << 30);
    }

    #[test]
    fn v80_strictly_larger_than_u280() {
        let (u, v) = (DeviceConfig::u280(), DeviceConfig::v80());
        assert!(v.peak_tflops > u.peak_tflops);
        assert!(v.hbm_bw > u.hbm_bw);
        assert!(v.resources.dsp > u.resources.dsp);
        assert!(v.resources.lut > u.resources.lut);
    }

    #[test]
    fn fits_respects_headroom() {
        let u = DeviceConfig::u280();
        let mut used = Resources::zero();
        used.dsp = u.resources.dsp * 0.8;
        assert!(u.fits(&used, 0.85));
        assert!(!u.fits(&used, 0.75));
    }
}
